"""Out-of-core streamed greedy == the in-memory drivers, pivot for pivot.

The streamed driver must be an exact refactor of the resident one, not an
approximation: these tests assert identical pivots, identical basis shapes
and span-equal Q across tile sizes {1 tile, M-divisible, ragged last tile},
dtypes {float32, complex64} (plus f64/c128 deep-tolerance paths) and all
three snapshot providers, and that a crash-interrupted checkpointed build
resumes to the identical result.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import dtype_tol, make_smooth_matrix
from repro.checkpoint import latest_step
from repro.core import rb_greedy, rb_greedy_stepwise, rb_greedy_streamed
from repro.data import (
    ArrayProvider, MemmapProvider, WaveformProvider, as_provider,
    create_snapshot_npy, write_snapshot_npy,
)

M_COLS = 120  # make_smooth_matrix default M

# tile regimes: whole matrix in 1 tile, an M-divisible width, a ragged
# last tile, and degenerate 1-column tiles
TILES = [M_COLS, 40, 33, 1]


def _assert_matches(ref, got, dtype, n):
    """Streamed result == in-memory result: same k, same pivots, same
    basis shape, errs/rnorms equal to dtype-scaled tolerance, span-equal
    (here: elementwise-close) Q."""
    k = int(ref.k)
    assert got.k == k
    assert got.Q.shape == ref.Q.shape  # bitwise-equal basis shapes
    assert np.array_equal(np.asarray(ref.pivots[:k]), got.pivots[:k])
    assert np.all(got.pivots[k:] == -1)
    tol = dtype_tol(dtype, n)
    scale = float(np.max(np.abs(np.asarray(ref.errs[:k])))) + 1e-30
    np.testing.assert_allclose(got.errs[:k], np.asarray(ref.errs[:k]),
                               rtol=tol, atol=tol * scale)
    np.testing.assert_allclose(got.rnorms[:k], np.asarray(ref.rnorms[:k]),
                               rtol=tol, atol=tol * scale)
    np.testing.assert_allclose(np.asarray(got.Q), np.asarray(ref.Q),
                               rtol=tol, atol=tol)
    if got.R is not None:
        np.testing.assert_allclose(got.R[:k], np.asarray(ref.R[:k]),
                                   rtol=tol,
                                   atol=tol * float(np.max(np.abs(got.R))))


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("tile_m", TILES)
def test_array_provider_matches_inmemory(dtype, tile_m):
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    tau = 1e-3
    ref_step = rb_greedy_stepwise(S, tau=tau)
    ref_chunk = rb_greedy(S, tau=tau)
    got = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=tile_m)
    _assert_matches(ref_step, got, dtype, S.shape[0])
    _assert_matches(ref_chunk, got, dtype, S.shape[0])


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("tile_m", [40, 33])
def test_memmap_provider_matches_inmemory(tmp_path, dtype, tile_m):
    S = make_smooth_matrix(dtype=dtype)
    path = write_snapshot_npy(tmp_path / "S.npy", S)
    prov = MemmapProvider(path)
    assert prov.shape == S.shape and prov.dtype == S.dtype
    ref = rb_greedy_stepwise(jnp.asarray(S), tau=1e-3)
    got = rb_greedy_streamed(prov, tau=1e-3, tile_m=tile_m)
    _assert_matches(ref, got, dtype, S.shape[0])


@pytest.mark.parametrize("fortran_order", [True, False])
def test_memmap_layouts_agree(tmp_path, fortran_order):
    """Row- and column-major .npy files stream to the same result."""
    S = make_smooth_matrix(dtype=np.complex64)
    path = write_snapshot_npy(tmp_path / "S.npy", S,
                              fortran_order=fortran_order)
    got = rb_greedy_streamed(path, tau=1e-3, tile_m=33)  # str -> provider
    ref = rb_greedy_stepwise(jnp.asarray(S), tau=1e-3)
    _assert_matches(ref, got, np.complex64, S.shape[0])


@pytest.mark.parametrize("dtype", [jnp.complex64, jnp.complex128])
@pytest.mark.parametrize("tile_m", [77, 20])
def test_waveform_provider_matches_inmemory(dtype, tile_m):
    """Generator provider: GW snapshots produced tile-by-tile on the fly
    select the same pivots as the greedy run on the materialized matrix."""
    from repro.gw import chirp_grid, frequency_grid

    f = frequency_grid(20.0, 256.0, 200)
    m1, m2 = chirp_grid(n_mc=11, n_eta=7)  # M = 77 (ragged at tile 20)
    prov = WaveformProvider(f, m1, m2, dtype=dtype, normalize=False)
    S = prov.materialize()
    assert S.shape == prov.shape
    tau = 1e-3 * float(jnp.max(jnp.linalg.norm(S, axis=0)))
    ref = rb_greedy_stepwise(S, tau=tau)
    got = rb_greedy_streamed(prov, tau=tau, tile_m=tile_m)
    _assert_matches(ref, got, np.dtype(dtype), S.shape[0])


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
def test_deep_tolerance_refresh_parity(dtype):
    """tau below the Eq.-(6.3) cancellation floor: the streamed refresh
    (tile-local exact residual recomputation) replays the stepwise
    driver's refresh decisions."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    ref = rb_greedy_stepwise(S, tau=1e-12)
    got = rb_greedy_streamed(ArrayProvider(S), tau=1e-12, tile_m=50)
    _assert_matches(ref, got, dtype, S.shape[0])
    from repro.core.errors import proj_error_max
    assert float(proj_error_max(S, got.Q[:, :got.k])) < 1e-11


def test_rank_guard_parity():
    """Exactly-low-rank snapshots: the streamed driver stops at numerical
    rank without adding junk directions, like the in-memory drivers."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((50, 8)) @ rng.standard_normal((8, 30))
    S = jnp.asarray(A)
    ref = rb_greedy_stepwise(S, tau=1e-18)
    got = rb_greedy_streamed(ArrayProvider(S), tau=1e-18, tile_m=7)
    _assert_matches(ref, got, np.float64, 50)
    assert got.k <= 9


def test_keep_r_false_and_callback():
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    seen = []
    got = rb_greedy_streamed(ArrayProvider(S), tau=1e-6, tile_m=33,
                             keep_R=False,
                             callback=lambda info: seen.append(info))
    assert got.R is None
    assert [info["k"] for info in seen] == list(range(1, got.k + 1))
    assert [info["pivot"] for info in seen] == list(got.pivots[:got.k])


def test_invalid_args_rejected():
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    with pytest.raises(ValueError, match="tile_m"):
        rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=0)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        rb_greedy_streamed(ArrayProvider(S), tau=1e-4, resume=True)


def test_create_snapshot_npy_roundtrip(tmp_path):
    """Tile-by-tile on-disk construction (for matrices larger than host
    memory) round-trips through MemmapProvider."""
    S = make_smooth_matrix(dtype=np.complex64)
    path = tmp_path / "big.npy"
    mm = create_snapshot_npy(path, S.shape, S.dtype)
    for lo in range(0, S.shape[1], 33):
        hi = min(lo + 33, S.shape[1])
        mm[:, lo:hi] = S[:, lo:hi]
    mm.flush()
    del mm
    prov = as_provider(path)
    np.testing.assert_array_equal(np.asarray(prov.materialize()), S)


# ------------------------------------------------ checkpoint / resume
class _CrashingProvider(ArrayProvider):
    """Raises after serving ``budget`` tiles — crash injection mid-sweep."""

    def __init__(self, S, budget):
        super().__init__(S)
        self.budget = budget

    def tile(self, lo, hi):
        if self.budget <= 0:
            raise IOError("injected crash")
        self.budget -= 1
        return super().tile(lo, hi)


# budgets chosen so the crash lands mid-sweep AFTER >= 1 checkpoint: the
# init pass consumes 4 tile fetches and each iteration 1 column + 4 tile
# fetches, so 7 dies on sweep tile 3 of basis 0 and 13 on sweep tile 4 of
# basis 1.
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("crash_after_tiles", [7, 13])
def test_crash_resume_identical(tmp_path, dtype, crash_after_tiles):
    """Kill the build mid-sweep, resume from the checkpoint: the final
    result is identical to an uninterrupted run (tile-cursor + residual
    caches round-trip through the checkpoint)."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    tau, tile_m = 1e-3, 33  # 4 tiles per sweep (ragged last)
    ref = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=tile_m)

    ck = tmp_path / "ck"
    crashing = _CrashingProvider(S, crash_after_tiles)
    with pytest.raises(IOError, match="injected crash"):
        rb_greedy_streamed(crashing, tau=tau, tile_m=tile_m,
                           checkpoint_dir=ck, checkpoint_every_tiles=1)
    assert latest_step(str(ck)) is not None  # something was persisted

    got = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=tile_m,
                             checkpoint_dir=ck, resume=True)
    assert got.k == ref.k
    assert np.array_equal(got.pivots, ref.pivots)
    np.testing.assert_array_equal(np.asarray(got.Q), np.asarray(ref.Q))
    np.testing.assert_array_equal(got.R, ref.R)
    np.testing.assert_array_equal(got.errs, ref.errs)


def test_resume_with_empty_dir_is_fresh_build(tmp_path):
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    ref = rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=40)
    got = rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=40,
                             checkpoint_dir=tmp_path / "empty", resume=True)
    assert got.k == ref.k
    assert np.array_equal(got.pivots, ref.pivots)


def test_resume_shape_mismatch_rejected(tmp_path):
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    ck = tmp_path / "ck"
    rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=40,
                       checkpoint_dir=ck)
    with pytest.raises(ValueError, match="mismatch"):
        rb_greedy_streamed(ArrayProvider(S[:, :60]), tau=1e-4, tile_m=40,
                           checkpoint_dir=ck, resume=True)


def test_resume_tiling_mismatch_rejected(tmp_path):
    """The checkpointed cursor is in tile units: resuming under a
    different tile_m would re-apply part of the in-flight sweep, so it
    must be refused rather than silently corrupt acc/R."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    ck = tmp_path / "ck"
    rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=40,
                       checkpoint_dir=ck)
    with pytest.raises(ValueError, match="tile_m mismatch"):
        rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=20,
                           checkpoint_dir=ck, resume=True)


def test_resume_dtype_mismatch_rejected(tmp_path):
    """Same-shaped provider with a different dtype (e.g. a regenerated
    snapshot file) must not silently mix precisions on resume."""
    S = make_smooth_matrix(dtype=np.complex64)
    ck = tmp_path / "ck"
    rb_greedy_streamed(ArrayProvider(jnp.asarray(S)), tau=1e-3, tile_m=40,
                       checkpoint_dir=ck)
    with pytest.raises(ValueError, match="dtype mismatch"):
        rb_greedy_streamed(ArrayProvider(jnp.asarray(S.real)), tau=1e-3,
                           tile_m=40, checkpoint_dir=ck, resume=True)


def test_resume_midsweep_backend_mismatch_rejected(tmp_path):
    """An in-flight sweep's partial acc carries one backend's float
    summation order; resuming it under another backend must be refused
    (completed sweeps are backend-portable)."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.complex64))
    ck = tmp_path / "ck"
    crashing = _CrashingProvider(S, 7)  # dies mid-sweep, ckpt every tile
    with pytest.raises(IOError, match="injected crash"):
        rb_greedy_streamed(crashing, tau=1e-3, tile_m=33, backend="xla",
                           checkpoint_dir=ck, checkpoint_every_tiles=1)
    with pytest.raises(ValueError, match="in-flight sweep"):
        rb_greedy_streamed(ArrayProvider(S), tau=1e-3, tile_m=33,
                           backend="xla_ref", checkpoint_dir=ck,
                           resume=True)
    # same backend resumes fine
    res = rb_greedy_streamed(ArrayProvider(S), tau=1e-3, tile_m=33,
                             backend="xla", checkpoint_dir=ck, resume=True)
    ref = rb_greedy_streamed(ArrayProvider(S), tau=1e-3, tile_m=33,
                             backend="xla")
    assert np.array_equal(res.pivots, ref.pivots)


def test_fresh_build_over_stale_checkpoints(tmp_path):
    """A fresh (resume=False) build into a directory holding an older
    run's steps must not be shadowed by them: its saves continue the step
    numbering, so a subsequent resume restores the NEW build's state."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    ck = tmp_path / "ck"
    old = rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=40,
                             checkpoint_dir=ck)
    new = rb_greedy_streamed(ArrayProvider(S), tau=1e-2, tile_m=40,
                             checkpoint_dir=ck)  # fresh, different tau
    assert new.k < old.k
    resumed = rb_greedy_streamed(ArrayProvider(S), tau=1e-2, tile_m=40,
                                 checkpoint_dir=ck, resume=True)
    assert resumed.k == new.k  # restored the fresh build, not the stale one
    assert np.array_equal(resumed.pivots, new.pivots)


def test_write_snapshot_npy_without_suffix(tmp_path):
    """np.save appends '.npy'; the returned path must be the real file."""
    S = make_smooth_matrix(dtype=np.float32)
    path = write_snapshot_npy(tmp_path / "snapshots", S)
    assert path.endswith(".npy")
    np.testing.assert_array_equal(
        np.asarray(MemmapProvider(path).materialize()), S)


# ------------------------------------------------ blocked (block_p > 1)
# The blocked stream must be (a) bitwise-invariant to the tiling, (b)
# provider-independent, and (c) the streamed twin of the resident chunked
# blocked driver.  Exact pivot parity vs the resident driver is asserted
# at f64/c128 (deterministic selection); f32/c64 families cluster
# near-degenerate candidates inside a block, so there the assertions are
# set/quality-level (the same caveat as every other parity suite).


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("p", [2, 4])
def test_blocked_stream_tile_size_invariant(dtype, p):
    """{single-tile, divisible, ragged, 1-column} tilings produce the same
    blocked build: identical selection (pivots, Q — bitwise) everywhere;
    the tracked VALUES (errs, R) are bitwise too except at degenerate
    1-column tiles, where XLA reduces the (p,N)x(N,1) panel GEMM in a
    different summation order than wide tiles (ulp-level, dtype-tol).

    Pinned to the production ``xla`` backend: the bitwise claim is a
    property of its deterministic real/plane-split GEMMs — ``xla_ref``'s
    complex GEMM reassociates with the tile width (oracle, not a
    reproducibility contract)."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    base = rb_greedy_streamed(ArrayProvider(S), tau=1e-3,
                              tile_m=M_COLS, block_p=p, backend="xla")
    assert base.block_p == p
    tol = dtype_tol(dtype, S.shape[0])
    scale = float(np.max(base.errs))
    for tile_m in TILES[1:]:
        got = rb_greedy_streamed(ArrayProvider(S), tau=1e-3,
                                 tile_m=tile_m, block_p=p, backend="xla")
        assert got.k == base.k
        np.testing.assert_array_equal(got.pivots, base.pivots)
        np.testing.assert_array_equal(np.asarray(got.Q),
                                      np.asarray(base.Q))
        if tile_m > 1:
            np.testing.assert_array_equal(got.errs, base.errs)
            np.testing.assert_array_equal(got.R, base.R)
        else:
            np.testing.assert_allclose(got.errs, base.errs,
                                       rtol=tol, atol=tol * scale)
            np.testing.assert_allclose(got.R, base.R, rtol=tol,
                                       atol=tol * float(np.max(np.abs(
                                           base.R))))


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
@pytest.mark.parametrize("p", [2, 4])
def test_blocked_stream_matches_resident_blocked(dtype, p):
    """Deep-precision exact parity: the blocked stream selects the same
    pivots and builds the same basis as the resident chunked blocked
    driver."""
    from repro.core.block_greedy import _rb_greedy_block_impl

    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    ref = _rb_greedy_block_impl(S, tau=1e-6, p=p)
    kr = int(ref.k)
    got = rb_greedy_streamed(ArrayProvider(S), tau=1e-6, tile_m=33,
                             block_p=p)
    assert got.k == kr
    np.testing.assert_array_equal(got.pivots[:kr],
                                  np.asarray(ref.pivots[:kr]))
    etol = dtype_tol(dtype, S.shape[0], factor=1e6)
    np.testing.assert_allclose(got.errs[:kr], np.asarray(ref.errs[:kr]),
                               rtol=etol,
                               atol=etol * float(np.max(ref.errs)))
    # Deep pivots' basis VECTORS are only comparable up to cancellation
    # amplification (orthogonalizing a column whose residual is ~6 decades
    # below its norm loses those digits to whatever summation order the
    # backend compiled) — so Q is checked by its algorithmic contract:
    # orthonormal and approximating to the tau the resident build reached.
    from repro.core.errors import orthogonality_defect, proj_error_max

    assert float(orthogonality_defect(got.Q[:, :kr])) < 1e-10
    ref_err = float(proj_error_max(S, ref.Q[:, :kr]))
    assert float(proj_error_max(S, got.Q[:, :kr])) < max(1e-6, 2 * ref_err)


@pytest.mark.parametrize("p", [2, 3])
def test_blocked_stream_provider_invariant(tmp_path, p):
    """Array, memmap and on-the-fly waveform providers stream to the same
    blocked build."""
    from repro.gw import chirp_grid, frequency_grid

    f = frequency_grid(20.0, 256.0, 200)
    m1, m2 = chirp_grid(n_mc=11, n_eta=7)  # M = 77 (ragged at tile 20)
    prov = WaveformProvider(f, m1, m2, dtype=jnp.complex64,
                            normalize=False)
    S = prov.materialize()
    tau = 1e-3 * float(jnp.max(jnp.linalg.norm(S, axis=0)))
    path = write_snapshot_npy(tmp_path / "S.npy", np.asarray(S))

    base = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=20,
                              block_p=p)
    for source in (MemmapProvider(path), prov):
        got = rb_greedy_streamed(source, tau=tau, tile_m=20, block_p=p)
        assert got.k == base.k
        np.testing.assert_array_equal(got.pivots, base.pivots)
        np.testing.assert_array_equal(np.asarray(got.Q),
                                      np.asarray(base.Q))


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_blocked_stream_quality(dtype):
    """Blocked streams meet the same tau as the stepwise stream with at
    most a few (<= p) extra bases — the staleness property, out of core."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    tau = 1e-3
    k_plain = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=40).k
    for p in (2, 4):
        got = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=40,
                                 block_p=p)
        from repro.core.errors import proj_error_max
        assert float(proj_error_max(S, got.Q[:, :got.k])) < tau
        assert got.k <= k_plain + p


# budget 9: init consumes 4 tile fetches, block 1's sweep 4 more — the
# crash lands on tile 2 of block 2's sweep, after >= 1 checkpoint.
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_blocked_crash_resume_identical(tmp_path, dtype):
    """Acceptance: checkpoint/resume of a blocked streamed build lands
    bit-identical to an uninterrupted run (pending panel + candidate
    folds + tile cursor all round-trip)."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    tau, tile_m, p = 1e-3, 33, 3
    ref = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=tile_m,
                             block_p=p)
    ck = tmp_path / "ck"
    crashing = _CrashingProvider(S, 9)
    with pytest.raises(IOError, match="injected crash"):
        rb_greedy_streamed(crashing, tau=tau, tile_m=tile_m, block_p=p,
                           checkpoint_dir=ck, checkpoint_every_tiles=1)
    assert latest_step(str(ck)) is not None
    got = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=tile_m,
                             block_p=p, checkpoint_dir=ck, resume=True)
    assert got.k == ref.k
    np.testing.assert_array_equal(got.pivots, ref.pivots)
    np.testing.assert_array_equal(np.asarray(got.Q), np.asarray(ref.Q))
    np.testing.assert_array_equal(got.R, ref.R)
    np.testing.assert_array_equal(got.errs, ref.errs)


def test_blocked_crash_resume_mid_panel(tmp_path):
    """A checkpoint taken MID-PANEL — the pending block already
    orthogonalized through the BLAS-3 panel path, its Eq.-(6.3) sweep only
    partially applied — resumes to the bit-identical build.  Asserts the
    restored state really was mid-panel (pending sweep, non-zero tile
    cursor), so the test cannot silently degrade into a block-boundary
    resume."""
    from repro.checkpoint.io import load_checkpoint_raw
    from repro.core.errors import orthogonality_defect

    S = jnp.asarray(make_smooth_matrix(dtype=np.complex64))
    tau, tile_m, p = 1e-3, 33, 4
    ref = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=tile_m,
                             block_p=p, panel_ortho=True)
    ck = tmp_path / "ck"
    # init = 4 tile fetches, block 1's sweep = 4 more: budget 10 dies on
    # tile 2 of block 2's sweep, after the mid-sweep checkpoint of tile 1.
    crashing = _CrashingProvider(S, 10)
    with pytest.raises(IOError, match="injected crash"):
        rb_greedy_streamed(crashing, tau=tau, tile_m=tile_m, block_p=p,
                           panel_ortho=True, checkpoint_dir=ck,
                           checkpoint_every_tiles=1)
    tree = load_checkpoint_raw(str(ck))
    assert int(tree["pending"]) == 1  # a panel sweep was in flight
    assert int(tree["cursor"]) > 0   # ... and had covered >= 1 tile
    assert np.any(np.asarray(tree["pending_Q"]) != 0)
    got = rb_greedy_streamed(ArrayProvider(S), tau=tau, tile_m=tile_m,
                             block_p=p, panel_ortho=True,
                             checkpoint_dir=ck, resume=True)
    assert got.k == ref.k
    np.testing.assert_array_equal(got.pivots, ref.pivots)
    np.testing.assert_array_equal(np.asarray(got.Q), np.asarray(ref.Q))
    np.testing.assert_array_equal(got.R, ref.R)
    assert float(orthogonality_defect(got.Q[:, :got.k])) < 1e-5


def test_blocked_resume_block_p_mismatch_rejected(tmp_path):
    """The checkpointed pending panel and candidate folds are
    width-block_p: resuming under another width must be refused."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    ck = tmp_path / "ck"
    rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=40, block_p=2,
                       checkpoint_dir=ck)
    with pytest.raises(ValueError, match="block_p mismatch"):
        rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=40,
                           block_p=3, checkpoint_dir=ck, resume=True)


@pytest.mark.parametrize("p", [1, 3])
def test_blocked_stream_respects_max_k(p):
    """max_k is a hard cap on ACCEPTED bases even when the final block
    would overrun it (the slot buffer's +p headroom is for holes)."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    got = rb_greedy_streamed(ArrayProvider(S), tau=1e-12, max_k=5,
                             tile_m=40, block_p=p)
    assert got.k <= 5
    assert np.all(got.pivots[got.k:] == -1)


def test_v1_checkpoint_lifts_and_resumes(tmp_path):
    """A v1 (pre-blocked, scalar-field) checkpoint must lift to v2 and
    resume losslessly — long-running out-of-core builds survive the
    upgrade."""
    from repro.checkpoint.io import save_checkpoint
    from repro.core.streaming import _StreamState

    S = jnp.asarray(make_smooth_matrix(dtype=np.complex64))
    ck = tmp_path / "ck"
    # run a partial stepwise build to get a genuine mid-build state...
    crashing = _CrashingProvider(S, 7)
    with pytest.raises(IOError, match="injected crash"):
        rb_greedy_streamed(crashing, tau=1e-3, tile_m=33,
                           checkpoint_dir=ck, checkpoint_every_tiles=1)
    # ...then rewrite its newest checkpoint in the v1 field layout
    from repro.checkpoint.io import load_checkpoint_raw, latest_step

    tree = load_checkpoint_raw(str(ck))
    v1 = {k: v for k, v in tree.items()}
    v1["version"] = np.asarray(1, np.int64)
    v1["best_val"] = v1.pop("best_vals")[0]
    v1["best_col"] = v1.pop("best_cols")[0]
    v1["pending_q"] = v1.pop("pending_Q")[:, 0]
    v1["pending_col"] = v1.pop("pending_cols")[0]
    v1["pending_err"] = v1.pop("pending_errs")[0]
    v1["pending_rnorm"] = v1.pop("pending_rnorms")[0]
    v1["pending_npass"] = v1["pending_npass"][0]
    v1["sweep_val"] = v1.pop("sweep_vals")[0]
    v1["sweep_col"] = v1.pop("sweep_cols")[0]
    for v2_only in ("block_p", "n_acc", "pending_ok"):
        v1.pop(v2_only, None)
    seq = latest_step(str(ck)) + 1
    save_checkpoint(v1, str(ck), seq)

    ref = rb_greedy_streamed(ArrayProvider(S), tau=1e-3, tile_m=33)
    got = rb_greedy_streamed(ArrayProvider(S), tau=1e-3, tile_m=33,
                             checkpoint_dir=ck, resume=True)
    assert got.k == ref.k
    np.testing.assert_array_equal(got.pivots, ref.pivots)
    np.testing.assert_array_equal(np.asarray(got.Q), np.asarray(ref.Q))
    np.testing.assert_array_equal(got.errs, ref.errs)


def test_blocked_stream_callback_counts_accepted():
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    seen = []
    got = rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=40,
                             block_p=4, keep_R=False,
                             callback=lambda info: seen.append(info))
    assert got.R is None
    assert [info["k"] for info in seen] == list(range(1, got.k + 1))
    assert [info["pivot"] for info in seen] == list(got.pivots[:got.k])


def test_checkpoints_are_pruned(tmp_path):
    """Per-tile checkpointing must not accumulate one full state copy per
    tile on disk — only the newest couple of steps survive."""
    import os
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    ck = tmp_path / "ck"
    rb_greedy_streamed(ArrayProvider(S), tau=1e-4, tile_m=20,
                       checkpoint_dir=ck, checkpoint_every_tiles=1)
    steps = [d for d in os.listdir(ck) if d.startswith("step_")]
    assert len(steps) <= 2
