"""Substrate tests: optimizer, data pipeline, checkpoint, trainer, serving."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint,
)
from repro.configs import get_reduced
from repro.data import SyntheticLMData
from repro.models import api
from repro.optim import (
    adamw_init, adamw_update, ef_state_init, ef_topk_compress, warmup_cosine,
)
from repro.serving import ServeEngine
from repro.training import make_train_step, train_state_init


@pytest.fixture(scope="module")
def cfg():
    return get_reduced("stablelm-3b")


# ------------------------------------------------------------------ optimizer
def test_adamw_descends_quadratic():
    w = {"x": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(w)
    for _ in range(200):
        g = {"x": 2 * w["x"]}
        w, opt = adamw_update(g, opt, w, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(w["x"]).max()) < 0.05


def test_grad_clipping():
    w = {"x": jnp.zeros(3)}
    opt = adamw_init(w)
    g = {"x": jnp.asarray([1e6, 0.0, 0.0])}
    w2, _ = adamw_update(g, opt, w, lr=1.0, clip_norm=1.0, weight_decay=0.0)
    # clipped update magnitude bounded by lr * 1/sqrt(...) ~ lr*sqrt(1/(1-b2))
    assert float(jnp.abs(w2["x"]).max()) < 20.0


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, 1e-3, 10, 100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert lrs[99] < lrs[50] < lrs[10] + 1e-9


def test_ef_topk_error_feedback():
    g = {"w": jnp.asarray(np.linspace(-1, 1, 100), jnp.float32)}
    ef = ef_state_init(g)
    comp, ef2 = ef_topk_compress(g, ef, ratio=0.1)
    nz = int(jnp.sum(comp["w"] != 0))
    assert nz <= 10
    # residual preserved: comp + ef2 == g
    np.testing.assert_allclose(
        np.asarray(comp["w"] + ef2["w"]), np.asarray(g["w"]), atol=1e-7
    )


# ----------------------------------------------------------------------- data
def test_data_deterministic_and_step_keyed():
    d = SyntheticLMData(vocab_size=64, seq_len=16, global_batch=4, seed=3)
    b1 = d.batch(7)
    b2 = d.batch(7)
    b3 = d.batch(8)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert np.array_equal(np.asarray(b1["tokens"][:, 1:]),
                          np.asarray(b1["labels"][:, :-1]))


def test_file_data(tmp_path):
    from repro.data import FileLMData
    arr = np.arange(10000, dtype=np.int32) % 97
    path = tmp_path / "toks.bin"
    arr.tofile(path)
    d = FileLMData(path=str(path), seq_len=32, global_batch=4)
    b = d.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert np.array_equal(np.asarray(d.batch(5)["tokens"]),
                          np.asarray(d.batch(5)["tokens"]))


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_crc(cfg, tmp_path):
    state = train_state_init(cfg, jax.random.key(0))
    save_checkpoint(state, str(tmp_path), 3)
    assert latest_step(str(tmp_path)) == 3
    restored = restore_checkpoint(state, str(tmp_path))
    eq = jax.tree.all(jax.tree.map(
        lambda a, b: bool(jnp.all(a == b)), state.params, restored.params))
    assert eq


def test_checkpoint_atomicity(cfg, tmp_path):
    """A .tmp directory never counts as a checkpoint."""
    state = train_state_init(cfg, jax.random.key(0))
    save_checkpoint(state, str(tmp_path), 1)
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpointer(cfg, tmp_path):
    state = train_state_init(cfg, jax.random.key(0))
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(state, 1)
    ck.save(state, 2)
    ck.wait()
    assert latest_step(str(tmp_path)) in (1, 2)
    restored = restore_checkpoint(state, str(tmp_path))
    assert int(restored.step) == int(state.step)


# -------------------------------------------------------------------- trainer
def test_training_reduces_loss(cfg):
    state = train_state_init(cfg, jax.random.key(0))
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=8)
    # run the full LR schedule (warmup + decay to total_steps): at 40/60
    # steps the loss is still mid-descent and the margin check is flaky
    step = make_train_step(cfg, base_lr=1e-3, warmup=5, total_steps=60)
    losses = []
    for i in range(60):
        state, m = step(state, data.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_microbatching_matches_full_batch(cfg):
    """Grad accumulation is numerically equivalent to the full batch."""
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=8)
    s1 = train_state_init(cfg, jax.random.key(0))
    s2 = jax.tree.map(jnp.copy, s1)
    f1 = make_train_step(cfg, n_microbatches=1, base_lr=1e-3, donate=False)
    f4 = make_train_step(cfg, n_microbatches=4, base_lr=1e-3, donate=False)
    b = data.batch(0)
    s1, m1 = f1(s1, b)
    s2, m2 = f4(s2, b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                            - b_.astype(jnp.float32)))),
        s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 2e-2  # bf16 params quantize updates


def test_compression_training_converges(cfg):
    state = train_state_init(cfg, jax.random.key(0), compression=True)
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=8)
    step = make_train_step(cfg, base_lr=1e-3, warmup=5, total_steps=60,
                           compression_ratio=0.25)
    losses = []
    for i in range(40):
        state, m = step(state, data.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


# -------------------------------------------------------------------- serving
def test_serve_engine_batched(cfg):
    params = api.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_len=48)
    batch = api.make_batch(cfg, jax.random.key(1), batch=4, seq=16)
    out = eng.generate(batch, 8)
    assert out.shape == (4, 8)
    out2 = eng.generate(batch, 8)
    assert np.array_equal(np.asarray(out), np.asarray(out2))  # greedy determinism


def test_serve_engine_sampling(cfg):
    params = api.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_len=48)
    batch = api.make_batch(cfg, jax.random.key(1), batch=2, seq=16)
    out = eng.generate(batch, 6, temperature=1.0, key=jax.random.key(7))
    assert out.shape == (2, 6)


def _stub_engine(vocab=16, batch=2):
    """ServeEngine with model calls stubbed out: generate()'s control
    flow (and its PRNG discipline) under test, no transformer cost."""
    eng = ServeEngine.__new__(ServeEngine)
    eng.cfg = None
    eng.params = None
    eng.max_len = 32
    logits = jnp.zeros((batch, vocab), jnp.float32)
    eng._prefill = lambda params, b: (logits, None)
    eng._decode = lambda params, tok, cache: (logits, cache)
    return eng


def test_serve_sampling_single_fold_per_step(monkeypatch):
    """Regression (PR-7 bugfix): sampled decode folded the key TWICE per
    step — once advancing the base key in the loop and once in _select —
    with overlapping indices, correlating the streams and reusing fold
    indices across steps.  The per-step key must be exactly
    fold_in(base_key, step), each step distinct."""
    eng = _stub_engine()
    base = jax.random.key(7)
    seen = []
    real_categorical = jax.random.categorical

    def recording(key, logits, *a, **kw):
        seen.append(np.asarray(jax.random.key_data(key)).copy())
        return real_categorical(key, logits, *a, **kw)

    monkeypatch.setattr(jax.random, "categorical", recording)
    n = 6
    eng.generate({"unused": None}, n, temperature=1.0, key=base)
    assert len(seen) == n + 1  # one select per step index 0..n
    expected = [
        np.asarray(jax.random.key_data(jax.random.fold_in(base, i)))
        for i in range(n + 1)
    ]
    for i, (got, want) in enumerate(zip(seen, expected)):
        assert np.array_equal(got, want), (
            f"step {i}: select key is not fold_in(base_key, {i}) — the "
            f"double-fold regressed")
    flat = np.stack([s.ravel() for s in seen])
    assert len(np.unique(flat, axis=0)) == len(seen)  # all distinct


def test_serve_sampling_deterministic_for_fixed_seed():
    """Same key -> identical sampled stream; different key -> different
    draws (on a stub whose logits are flat, so tokens are pure PRNG)."""
    eng = _stub_engine(vocab=1024)
    out1 = eng.generate({"unused": None}, 8, temperature=1.0,
                        key=jax.random.key(3))
    out2 = eng.generate({"unused": None}, 8, temperature=1.0,
                        key=jax.random.key(3))
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
    out3 = eng.generate({"unused": None}, 8, temperature=1.0,
                        key=jax.random.key(4))
    assert not np.array_equal(np.asarray(out1), np.asarray(out3))
