"""Algorithm 3 (RB-greedy) invariants and the paper's corollaries."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_smooth_matrix
from repro.core import rb_greedy
from repro.core.greedy import rb_greedy_scan
from repro.core.errors import (
    greedy_error_determinant_identity,
    orthogonality_defect,
    per_column_errors,
    proj_error_max,
)


@pytest.fixture(params=[np.float64, np.complex128])
def S(request):
    return jnp.asarray(make_smooth_matrix(dtype=request.param))


def test_orthonormal_basis(S):
    res = rb_greedy(S, tau=1e-8)
    k = int(res.k)
    Q = res.Q[:, :k]
    assert float(orthogonality_defect(Q)) < 1e-12


def test_stopping_criterion(S):
    """Cor 5.6: error after k bases equals the recorded R(k+1,k+1)."""
    res = rb_greedy(S, tau=1e-8)
    k = int(res.k)
    # errs[j] is the max residual BEFORE adding basis j == after j bases.
    # Eq. (6.3) tracks err^2 with an absolute eps*|s|^2 floor, so the
    # relative agreement degrades as err -> sqrt(eps)*|s|.
    norm2 = float(jnp.max(jnp.sum(jnp.abs(S) ** 2, axis=0)))
    for j in (2, 5, min(8, k - 1)):
        true = float(proj_error_max(S, res.Q[:, :j]))
        rec = float(res.errs[j])
        floor = (2.3e-16 * norm2) ** 0.5
        assert abs(true - rec) <= 1e-6 * true + floor


def test_errors_non_increasing(S):
    res = rb_greedy(S, tau=1e-8)
    k = int(res.k)
    errs = np.asarray(res.errs[:k])
    assert np.all(np.diff(errs) <= 1e-12)  # Prop 5.3: R(k,k) non-increasing


def test_r_diagonal_equals_errs(S):
    """R[j, pivots[j]] (pivoted diagonal) equals the recorded error.

    The diagonal |R(j,j)| = q_j^H s_pivot is EXACT while errs[j] is the
    Eq.-6.3 tracked value with its eps*|s|^2 cancellation floor — compare
    with a floor-aware tolerance (their divergence below the floor is the
    very phenomenon the refresh mode corrects).
    """
    res = rb_greedy(S, tau=1e-8)
    k = int(res.k)
    diag = np.asarray(
        jnp.abs(res.R[jnp.arange(k), res.pivots[:k]])
    )
    errs = np.asarray(res.errs[:k])
    norm2 = float(jnp.max(jnp.sum(jnp.abs(S) ** 2, axis=0)))
    floor = (2.3e-16 * norm2) ** 0.5
    # the tracked value is floor-NOISE, not floor-bounded: allow a few x
    assert np.all(np.abs(diag - errs) <= 1e-6 * errs + 5 * floor)


def test_max_norm_error_below_tau(S):
    tau = 1e-6
    res = rb_greedy(S, tau=tau)
    k = int(res.k)
    errs = per_column_errors(S, res.Q[:, :k])
    assert float(jnp.max(errs)) < tau * 1.01


def test_determinant_identity():
    """Cor 5.7 on a small well-conditioned case."""
    rng = np.random.default_rng(1)
    A = rng.standard_normal((30, 12))
    # make singular values decay mildly so products stay sane
    U, s, Vt = np.linalg.svd(A, full_matrices=False)
    s = np.linspace(3.0, 1.0, 12)
    S = jnp.asarray(U @ np.diag(s) @ Vt)
    res = rb_greedy(S, tau=1e-12)
    k = 6
    # determinant identity applies to the pivoted submatrix spectrum
    Sk1 = np.asarray(S)[:, np.asarray(res.pivots[: k + 1])]
    sig = np.linalg.svd(Sk1, compute_uv=False)
    lhs = float(res.errs[k])
    rhs = float(
        greedy_error_determinant_identity(
            jnp.asarray(sig), res.errs, k
        )
    )
    assert lhs == pytest.approx(rhs, rel=1e-6)


def test_scan_variant_matches_driver(S):
    res = rb_greedy(S, tau=1e-6, refresh="never")
    scan = rb_greedy_scan(S, 1e-6, max_k=int(res.k) + 3)
    k = int(res.k)
    assert int(scan.k) == k
    assert np.array_equal(np.asarray(res.pivots[:k]),
                          np.asarray(scan.pivots[:k]))


def test_deep_tolerance_refresh(S):
    """Beyond-paper: refresh mode reaches below the Eq-6.3 floor."""
    res = rb_greedy(S, tau=1e-12)
    k = int(res.k)
    true = float(proj_error_max(S, res.Q[:, :k]))
    assert true < 1e-11
    assert float(orthogonality_defect(res.Q[:, :k])) < 1e-12


def test_rank_guard_stops_on_numerical_rank(S):
    """tau below machine noise must not produce junk bases."""
    res = rb_greedy(S, tau=1e-18)
    k = int(res.k)
    assert k < min(S.shape)  # stopped before exhausting columns
    assert float(orthogonality_defect(res.Q[:, :k])) < 1e-10


def test_hoffmann_pass_counts(S):
    """Paper: nu_j <= 3 'typically less than 3' with kappa=2."""
    res = rb_greedy(S, tau=1e-10)
    k = int(res.k)
    passes = np.asarray(res.n_ortho_passes[:k])
    assert passes.max() <= 3
    assert passes.min() >= 1
