"""Public-API snapshot: accidental surface breaks fail fast.

Pins (a) the exported names of ``repro.api`` / ``repro.core`` /
``repro.data``, (b) the exact signature of ``build_basis``, (c) the
``ReductionSpec`` fields and defaults, and (d) the ``ReducedBasis``
method surface.  Changing any of these is an intentional, reviewed act:
update the snapshot here in the same commit.
"""

import dataclasses
import inspect

import repro.api
import repro.core
import repro.data
from repro.api import ReducedBasis, ReductionSpec, build_basis


def test_repro_api_exports():
    assert sorted(repro.api.__all__) == [
        "ReducedBasis",
        "ReducedBasisSet",
        "ReductionSpec",
        "STRATEGIES",
        "build_basis",
        "build_basis_set",
        "device_memory_budget",
    ]
    for name in repro.api.__all__:
        assert hasattr(repro.api, name), name


def test_strategies_pinned():
    assert repro.api.STRATEGIES == (
        "pod", "mgs", "greedy", "block_greedy", "streamed", "distributed",
        "randomized", "sketch+greedy", "batched", "auto",
    )


def test_build_basis_signature_pinned():
    assert str(inspect.signature(build_basis)) == \
        "(spec: 'ReductionSpec | None' = None, **kwargs) -> 'ReducedBasis'"


def test_reduction_spec_fields_pinned():
    fields = [(f.name, f.default) for f in dataclasses.fields(ReductionSpec)]
    assert fields == [
        ("source", None),
        ("strategy", "auto"),
        ("tau", 1e-6),
        ("max_k", None),
        ("backend", None),
        ("chunk", 16),
        ("tile_m", 8192),
        ("mesh", None),
        # PR 4: block_p default 1 = stepwise everywhere; > 1 opts every
        # blocked execution path (block_greedy / streamed / distributed)
        # into p pivots per sweep ("auto" may raise it, logged)
        ("block_p", 1),
        # PR 5: blocked ortho goes BLAS-3 by default (panel_ortho); the
        # resident blocked driver can retune the live panel width from
        # the rank guard's rejection rate (adaptive_block, p-trajectory
        # recorded in provenance)
        ("panel_ortho", True),
        ("adaptive_block", False),
        ("kappa", 2.0),
        ("max_passes", 3),
        ("refresh", "auto"),
        ("refresh_safety", 100.0),
        ("keep_R", True),
        # PR 6: workdir owns the atomic build->artifact lifecycle
        # (checkpoints in <workdir>/build/, finalized artifact in
        # <workdir>; mutually exclusive with checkpoint_dir)
        ("workdir", None),
        ("checkpoint_dir", None),
        ("checkpoint_every_tiles", 0),
        ("resume", False),
        ("callback", None),
        ("memory_budget_bytes", None),
        # PR 4: the auto DRAM-roofline machine model's knobs
        ("bandwidth_gbps", None),
        ("peak_gflops", None),
        ("cache_bytes", None),
        # PR 7: randomized range-finder knobs (randomized / sketch+greedy)
        ("sketch_p", 10),
        ("sketch_power", 0),
        ("sketch_seed", 0),
        ("sketch_kind", "gaussian"),
        # PR 9: lane count for the batched many-basis strategy (tau may
        # also be a length-B sequence -- its annotation widened to Any)
        ("batch", None),
    ]


def test_reduced_basis_surface_pinned():
    public = sorted(
        n for n in vars(ReducedBasis)
        if not n.startswith("_") and callable(getattr(ReducedBasis, n))
    )
    assert public == [
        "eim", "enrich", "load", "per_column_errors", "project",
        "reconstruct", "roq_weights", "save",
    ]
    assert [f.name for f in dataclasses.fields(ReducedBasis)] == [
        "Q", "pivots", "errs", "k", "R", "provenance",
    ]


def test_repro_core_exports_stable():
    """The legacy driver names keep importing (wrappers stay in place)."""
    assert sorted(repro.core.__all__) == sorted([
        "pod", "pod_basis", "mgs_pivoted_qr", "GreedyResult", "rb_greedy",
        "rb_greedy_stepwise", "rb_greedy_streamed", "StreamedGreedyResult",
        "rb_randomized_streamed", "RandomizedSketchResult",
        "estimate_rank", "RankEstimate",
        "batch_rb_greedy", "BatchGreedyResult",
        "imgs_orthogonalize", "optimal_rrqr", "reconstruction", "eim_nodes",
        "empirical_interpolant", "roq_weights", "default_backend",
        "resolve_backend", "set_default_backend",
    ])


def test_repro_data_exports_stable():
    assert sorted(repro.data.__all__) == sorted([
        "SyntheticLMData", "FileLMData", "SnapshotProvider",
        "ArrayProvider", "FaultPlan", "FaultyProvider", "MemmapProvider",
        "WaveformProvider", "as_provider", "create_snapshot_npy",
        "materialize_source", "write_snapshot_npy",
        "BandSplit", "band_split",
    ])
