"""Unit tests for repro.timing: the shared quantile helper (serving
metrics snapshots + the load harness both use it) and steady_min."""

import numpy as np
import pytest

from repro.timing import percentiles, steady_min


def test_percentiles_matches_numpy_linear():
    rng = np.random.default_rng(7)
    xs = rng.standard_normal(257).tolist()
    qs = (0.0, 10.0, 50.0, 95.0, 99.0, 100.0)
    got = percentiles(xs, qs)
    want = np.percentile(xs, qs)  # numpy default = linear interpolation
    for q, w in zip(qs, want):
        assert got[q] == pytest.approx(float(w), rel=1e-12), q


def test_percentiles_min_median_max_exact():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    got = percentiles(xs, (0, 50, 100))
    assert got[0] == 1.0
    assert got[50] == 3.0
    assert got[100] == 5.0


def test_percentiles_single_sample_is_flat():
    got = percentiles([2.5], (0, 50, 99, 100))
    assert set(got.values()) == {2.5}


def test_percentiles_interpolates_between_order_stats():
    # two samples: p50 is the midpoint under linear interpolation
    assert percentiles([0.0, 1.0], (50,))[50] == pytest.approx(0.5)
    assert percentiles([0.0, 1.0], (75,))[75] == pytest.approx(0.75)


def test_percentiles_accepts_any_iterable_of_numbers():
    got = percentiles((x for x in [3, 1, 2]), (100,))
    assert got[100] == 3.0


def test_percentiles_default_qs():
    got = percentiles([1.0, 2.0, 3.0])
    assert sorted(got) == [50.0, 95.0, 99.0]


def test_percentiles_empty_raises():
    with pytest.raises(ValueError, match="empty"):
        percentiles([])


@pytest.mark.parametrize("q", [-0.1, 100.1, 1000])
def test_percentiles_out_of_range_q_raises(q):
    with pytest.raises(ValueError, match="outside"):
        percentiles([1.0], (q,))


def test_steady_min_calls_and_scale():
    calls = []
    dt = steady_min(lambda: calls.append(1), per=2, repeats=4, warmup=3)
    assert len(calls) == 3 + 4
    assert dt >= 0.0
