"""Theorem 5.1 (optimal RRQR) and Corollary 5.2 (exact-rank case)."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_smooth_matrix
from repro.core import optimal_rrqr
from repro.core.rrqr import rrqr_error_2norm


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
@pytest.mark.parametrize("k", [3, 8, 15])
def test_optimal_rrqr_matches_pod_error(dtype, k):
    """|S - Q_k Q_k^H S|_2 == sigma_{k+1} (POD-optimal, Eq. 5.5)."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    res = optimal_rrqr(S, k)
    err = float(rrqr_error_2norm(S, res.Qk))
    assert err == pytest.approx(float(res.sigmas[k]), rel=1e-6, abs=1e-12)


def test_optimal_rrqr_orthonormal():
    S = jnp.asarray(make_smooth_matrix())
    res = optimal_rrqr(S, 10)
    G = res.Qk.conj().T @ res.Qk
    assert np.allclose(np.asarray(G), np.eye(10), atol=1e-10)


def test_exact_rank_reconstruction(rng):
    """Cor 5.2: ordinary rank k => S == Q_k R exactly."""
    k = 6
    A = rng.standard_normal((40, k)) @ rng.standard_normal((k, 25))
    S = jnp.asarray(A)
    res = optimal_rrqr(S, k)
    recon = res.Qk @ res.R
    assert np.allclose(np.asarray(recon), A, atol=1e-10)


def test_rrqr_error_bounds_interlace():
    """sigma_{k+1} <= |S - QQ^H S|_2 for ANY rank-k orthonormal Q (POD
    optimality), with equality for the Thm-5.1 construction."""
    S = jnp.asarray(make_smooth_matrix())
    sig = np.linalg.svd(np.asarray(S), compute_uv=False)
    from repro.core import rb_greedy
    g = rb_greedy(S, tau=1e-10)
    for k in (3, 6, 9):
        greedy_err = float(
            jnp.linalg.norm(
                S - g.Q[:, :k] @ (g.Q[:, :k].conj().T @ S), ord=2
            )
        )
        assert greedy_err >= sig[k] - 1e-10
        opt_err = float(rrqr_error_2norm(S, optimal_rrqr(S, k).Qk))
        assert opt_err <= greedy_err + 1e-10
