"""Theorem 5.1 (optimal RRQR) and Corollary 5.2 (exact-rank case)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from conftest import dtype_tol, make_smooth_matrix
from repro.core import optimal_rrqr
from repro.core.rrqr import rrqr_error_2norm


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
@pytest.mark.parametrize("k", [3, 8, 15])
def test_optimal_rrqr_matches_pod_error(dtype, k):
    """|S - Q_k Q_k^H S|_2 == sigma_{k+1} (POD-optimal, Eq. 5.5)."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    res = optimal_rrqr(S, k)
    err = float(rrqr_error_2norm(S, res.Qk))
    assert err == pytest.approx(float(res.sigmas[k]), rel=1e-6, abs=1e-12)


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("k", [3, 6])
def test_optimal_rrqr_exactness_low_precision(dtype, k):
    """Theorem-5.1 exactness holds in the GW production dtypes too
    (complex64, float32) — up to an eps*sqrt(N)-scaled absolute floor set
    by sigma_1 (sigma_{k+1} of this family decays below f32 resolution, so
    a pure relative check would be ill-posed)."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    res = optimal_rrqr(S, k)
    err = float(rrqr_error_2norm(S, res.Qk))
    sig0, sigk = float(res.sigmas[0]), float(res.sigmas[k])
    atol = dtype_tol(dtype, n=S.shape[0], factor=100.0) * sig0
    assert abs(err - sigk) <= atol


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 5_000), k=st.integers(1, 8))
def test_optimal_rrqr_exactness_property_complex64(seed, k):
    """Property: Thm-5.1 exactness on random complex64 low-rank + noise
    matrices (error == sigma_{k+1} at dtype-scaled tolerance)."""
    rng = np.random.default_rng(seed)
    n, m = 30, 24
    r = k + 2
    A = (rng.standard_normal((n, r)) + 1j * rng.standard_normal((n, r))) @ \
        (rng.standard_normal((r, m)) + 1j * rng.standard_normal((r, m)))
    A = A + 1e-4 * (rng.standard_normal((n, m))
                    + 1j * rng.standard_normal((n, m)))
    S = jnp.asarray(A.astype(np.complex64))
    res = optimal_rrqr(S, k)
    err = float(rrqr_error_2norm(S, res.Qk))
    sig0, sigk = float(res.sigmas[0]), float(res.sigmas[k])
    atol = dtype_tol(np.complex64, n=n, factor=100.0) * sig0
    assert abs(err - sigk) <= atol
    # and the basis is orthonormal at working precision
    G = np.asarray(res.Qk.conj().T @ res.Qk)
    assert np.allclose(G, np.eye(k), atol=dtype_tol(np.complex64, n=n))


def test_optimal_rrqr_orthonormal():
    S = jnp.asarray(make_smooth_matrix())
    res = optimal_rrqr(S, 10)
    G = res.Qk.conj().T @ res.Qk
    assert np.allclose(np.asarray(G), np.eye(10), atol=1e-10)


def test_exact_rank_reconstruction(rng):
    """Cor 5.2: ordinary rank k => S == Q_k R exactly."""
    k = 6
    A = rng.standard_normal((40, k)) @ rng.standard_normal((k, 25))
    S = jnp.asarray(A)
    res = optimal_rrqr(S, k)
    recon = res.Qk @ res.R
    assert np.allclose(np.asarray(recon), A, atol=1e-10)


def test_rrqr_error_bounds_interlace():
    """sigma_{k+1} <= |S - QQ^H S|_2 for ANY rank-k orthonormal Q (POD
    optimality), with equality for the Thm-5.1 construction."""
    S = jnp.asarray(make_smooth_matrix())
    sig = np.linalg.svd(np.asarray(S), compute_uv=False)
    from repro.core import rb_greedy
    g = rb_greedy(S, tau=1e-10)
    for k in (3, 6, 9):
        greedy_err = float(
            jnp.linalg.norm(
                S - g.Q[:, :k] @ (g.Q[:, :k].conj().T @ S), ord=2
            )
        )
        assert greedy_err >= sig[k] - 1e-10
        opt_err = float(rrqr_error_2norm(S, optimal_rrqr(S, k).Qk))
        assert opt_err <= greedy_err + 1e-10
