"""Fault tolerance: crash injection + supervisor restart ==
bit-identical continuation (checkpoint atomicity + step-keyed data)."""

import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_train(args, env_extra=None, check=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    if env_extra:
        env.update(env_extra)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        env=env, capture_output=True, text=True, timeout=600,
    )
    if check:
        assert proc.returncode == 0, proc.stderr[-2000:]
    return proc


COMMON = ["--arch", "stablelm-3b", "--reduced", "--steps", "30",
          "--seq", "32", "--batch", "4", "--ckpt-every", "10",
          "--log-every", "30"]


@pytest.mark.slow
def test_crash_restart_bit_identical(tmp_path):
    ref_dir = tmp_path / "ref"
    ft_dir = tmp_path / "ft"

    # uninterrupted run
    _run_train(COMMON + ["--ckpt-dir", str(ref_dir)])

    # crash at step 17 (after the step-10 checkpoint), then resume
    p = _run_train(COMMON + ["--ckpt-dir", str(ft_dir), "--crash-at", "17"],
                   check=False)
    assert p.returncode == 42
    _run_train(COMMON + ["--ckpt-dir", str(ft_dir)])

    # final checkpoints must be bit-identical
    import json
    ref_step = sorted(os.listdir(ref_dir))[-1]
    ft_step = sorted(os.listdir(ft_dir))[-1]
    assert ref_step == ft_step
    for fname in sorted(os.listdir(ref_dir / ref_step)):
        if fname.endswith(".npy"):
            a = np.load(ref_dir / ref_step / fname)
            b = np.load(ft_dir / ft_step / fname)
            assert np.array_equal(a, b), f"mismatch in {fname}"
        elif fname == "manifest.json":
            ma = json.load(open(ref_dir / ref_step / fname))
            mb = json.load(open(ft_dir / ft_step / fname))
            assert ma == mb


@pytest.mark.slow
@pytest.mark.timing  # subprocess restart pacing flakes on the noisy box
def test_supervisor_restarts_crashed_job(tmp_path):
    from repro.launch.supervisor import run_supervised

    ckpt = tmp_path / "ck"
    log = tmp_path / "run.log"
    cmd = [sys.executable, "-m", "repro.launch.train"] + COMMON + [
        "--ckpt-dir", str(ckpt), "--crash-at", "17"]
    env_backup = dict(os.environ)
    os.environ["PYTHONPATH"] = SRC
    os.environ["JAX_PLATFORMS"] = "cpu"
    try:
        # first attempt crashes at 17; the restart resumes from step 10 and
        # passes 17 (crash-at only fires when the step is executed afresh —
        # the resumed process starts at step 10 and hits 17 again; use a
        # crash-once marker instead: crash only if no checkpoint >= 17 yet).
        # Simpler: supervise a command that crashes, then run to completion
        # manually — here we only assert the supervisor retries and returns
        # the final rc of the last attempt.
        rc = run_supervised(cmd, max_restarts=1, log_path=str(log))
        assert rc == 42  # both attempts crash at 17 -> supervisor gives up
        # but checkpoints survived atomically:
        from repro.checkpoint import latest_step
        assert latest_step(str(ckpt)) == 10
    finally:
        os.environ.clear()
        os.environ.update(env_backup)
