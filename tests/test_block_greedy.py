"""Block RB-greedy (beyond-paper §Perf): quality + cost properties.

Block builds run through the front door
(``build_basis(strategy="block_greedy")``; the direct ``rb_greedy_block``
entry point is deprecated).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dtype_tol, make_smooth_matrix
from repro.api import build_basis
from repro.core import rb_greedy
from repro.core.block_greedy import (
    _rb_greedy_block_impl,
    block_greedy_step,
    rb_greedy_block_stepwise,
)
from repro.core.errors import orthogonality_defect, proj_error_max
from repro.core.greedy import greedy_init, panel_imgs_orthogonalize


def block_front_door(S, tau, p):
    return build_basis(source=S, strategy="block_greedy", tau=tau,
                       block_p=p)


@pytest.fixture(scope="module")
def gw_S():
    from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid
    f = frequency_grid(20.0, 512.0, 600)
    m1, m2 = chirp_grid(n_mc=32, n_eta=8)
    return build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128)


@pytest.mark.parametrize("p", [2, 4, 8])
def test_block_greedy_meets_tau(gw_S, p):
    tau = 1e-5
    res = block_front_door(gw_S, tau=tau, p=p)
    Q = res.Q
    assert float(proj_error_max(gw_S, Q)) < tau
    assert float(orthogonality_defect(Q)) < 1e-10


@pytest.mark.parametrize("p", [2, 4])
def test_block_greedy_basis_count_near_plain(gw_S, p):
    """Pivot staleness costs at most ~15% extra bases on smooth families."""
    tau = 1e-5
    k_plain = int(rb_greedy(gw_S, tau=tau).k)
    k_block = block_front_door(gw_S, tau=tau, p=p).k
    assert k_block <= int(k_plain * 1.15) + p


def test_block_p1_matches_plain():
    S = jnp.asarray(make_smooth_matrix())
    tau = 1e-6
    plain = rb_greedy(S, tau=tau)
    blk = block_front_door(S, tau=tau, p=1)
    kp, kb = int(plain.k), blk.k
    assert abs(kp - kb) <= 1
    k = min(kp, kb)
    assert np.array_equal(np.asarray(plain.pivots[:k]),
                          np.asarray(blk.pivots[:k]))


# ------------------------------------- chunked driver vs stepwise oracle ----
# Parity is asserted above the Eq.-(6.3) cancellation floor: below it the
# near-degenerate candidates inside a block are separated by less than the
# f32 tracking noise and acceptance order legitimately depends on float
# summation details (the same caveat every parity suite documents).


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("p", [1, 2, 4, 8])
def test_chunked_driver_matches_stepwise_oracle(dtype, p):
    """The jitted while_loop driver (top-p + joint IMGS + fused panel
    sweep in-trace) is pivot-for-pivot identical to the eager per-block
    oracle, holes and all."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    a = _rb_greedy_block_impl(S, tau=1e-3, p=p)
    b = rb_greedy_block_stepwise(S, tau=1e-3, p=p)
    k = int(a.k)
    assert int(b.k) == k
    assert k >= 4
    assert np.array_equal(np.asarray(a.pivots), np.asarray(b.pivots))
    np.testing.assert_array_equal(np.asarray(a.Q), np.asarray(b.Q))
    np.testing.assert_array_equal(np.asarray(a.R), np.asarray(b.R))


@pytest.mark.parametrize("p", [2, 4])
def test_chunked_driver_matches_oracle_deep_tolerance(p):
    """Deep-tolerance (refresh-exercising) parity in c128, where the
    Eq.-(6.3) floor sits far below the taus tested."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.complex128))
    for tau in (1e-5, 1e-8):
        a = _rb_greedy_block_impl(S, tau=tau, p=p)
        b = rb_greedy_block_stepwise(S, tau=tau, p=p)
        assert int(a.k) == int(b.k)
        assert np.array_equal(np.asarray(a.pivots), np.asarray(b.pivots))


@pytest.mark.parametrize("chunk", [1, 3])
def test_chunk_size_invariance(chunk):
    """The chunk boundary is an execution detail: any chunk size yields
    the same build."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.complex64))
    ref = _rb_greedy_block_impl(S, tau=1e-3, p=3)
    got = _rb_greedy_block_impl(S, tau=1e-3, p=3, chunk=chunk)
    assert int(got.k) == int(ref.k)
    assert np.array_equal(np.asarray(got.pivots), np.asarray(ref.pivots))
    np.testing.assert_array_equal(np.asarray(got.Q), np.asarray(ref.Q))


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_blocked_tau_and_extra_bases_property(dtype):
    """Acceptance property: the blocked driver reaches the same tau as
    stepwise greedy with at most a few extra bases (pivot staleness),
    across block widths."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    tau = 1e-3
    k_plain = int(rb_greedy(S, tau=tau).k)
    for p in (2, 4, 8):
        res = _rb_greedy_block_impl(S, tau=tau, p=p)
        k = int(res.k)
        assert float(proj_error_max(S, res.Q[:, :k])) < tau
        assert k <= k_plain + p  # a few extra bases, never more than p
        assert float(orthogonality_defect(res.Q[:, :k])) < 1e-5


@pytest.mark.parametrize("p", [1, 4])
def test_blocked_respects_max_k(p):
    """max_k caps ACCEPTED bases even when the final block would overrun
    it — across the chunked driver, the stepwise oracle and the front
    door (the contract 'auto' relies on when it swaps greedy for
    block_greedy)."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.float32))
    for res in (
        _rb_greedy_block_impl(S, tau=1e-12, p=p, max_k=6),
        rb_greedy_block_stepwise(S, tau=1e-12, p=p, max_k=6),
        build_basis(source=S, strategy="block_greedy", tau=1e-12,
                    block_p=p, max_k=6),
    ):
        assert int(res.k) <= 6


def test_front_door_blocked_forwards_callback():
    """spec.callback reaches the blocked driver (chunk cadence), so
    progress hooks don't go dark when 'auto' picks block_greedy."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.float32))
    seen = []
    basis = build_basis(source=S, strategy="block_greedy", tau=1e-3,
                        block_p=2, callback=seen.append)
    assert basis.k >= 4
    assert len(seen) >= 1  # fired at least once per chunk
    assert int(seen[-1].k) >= basis.k  # slot counter covers accepted


def test_blocked_rejected_candidates_leave_no_holes():
    """Rank-rejected in-block candidates are compacted away: every column
    of the returned Q up to k is a unit vector and pivots[:k] >= 0."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((60, 6)) @ rng.standard_normal((6, 40))
    res = _rb_greedy_block_impl(jnp.asarray(A), tau=1e-12, p=4)
    k = int(res.k)
    assert k <= 7  # numerical rank, not the slot budget
    norms = np.linalg.norm(np.asarray(res.Q), axis=0)
    np.testing.assert_allclose(norms[:k], 1.0, rtol=1e-6)
    assert np.all(norms[k:] == 0.0)
    assert np.all(np.asarray(res.pivots[:k]) >= 0)
    assert np.all(np.asarray(res.pivots[k:]) == 0)


# --------------------------------------------- panel orthogonalization ----


@pytest.mark.parametrize("backend", ["xla", "xla_ref"])
@pytest.mark.parametrize("dtype",
                         [np.float32, np.complex64, np.complex128])
def test_panel_ortho_orthogonality_bound(dtype, backend):
    """Acceptance: the panel-IMGS blocked basis satisfies the iterated-GS
    orthogonality level |Q^H Q - I| <= dtype_tol across dtypes and both
    backend matrix legs (incl. near-degenerate in-block candidates)."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    res = _rb_greedy_block_impl(S, tau=1e-3, p=8, backend=backend)
    k = int(res.k)
    assert k >= 4
    Q = np.asarray(res.Q[:, :k], np.complex128
                   if np.issubdtype(dtype, np.complexfloating)
                   else np.float64)
    defect = np.abs(Q.conj().T @ Q - np.eye(k)).max()
    assert defect <= dtype_tol(np.zeros((), dtype).real.dtype,
                               S.shape[0]), defect


@pytest.mark.parametrize("dtype", [np.float32, np.complex64, np.complex128])
def test_panel_matches_sequential_pivots(dtype):
    """Panel and p-sequential orthogonalization build equivalent
    reductions: in deep precision (f64-real floors far below tau) the
    selection is pivot-for-pivot identical; in f32/c64 near-tied
    residuals inside the final blocks may legitimately resolve
    differently between the two float summation orders (the caveat every
    parity suite documents), so the assertion there is the algorithmic
    contract — same basis count, same early pivots, tau met."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    tau = 1e-3
    a = _rb_greedy_block_impl(S, tau=tau, p=4, panel=True)
    b = _rb_greedy_block_impl(S, tau=tau, p=4, panel=False)
    k = int(a.k)
    assert int(b.k) == k
    assert k >= 4
    if dtype == np.complex128:
        assert np.array_equal(np.asarray(a.pivots), np.asarray(b.pivots))
    else:
        # the first block is selected from identical residuals: exact
        half = min(4, k)
        assert np.array_equal(np.asarray(a.pivots[:half]),
                              np.asarray(b.pivots[:half]))
    for res in (a, b):
        assert float(proj_error_max(S, res.Q[:, :k])) < tau


def test_panel_imgs_orthogonalize_rank_guard(rng):
    """A within-block dependent candidate is rejected (zero column) and
    later candidates never see it; accepted columns are orthonormal
    against Q and each other."""
    N, K = 120, 9
    Q = jnp.asarray(np.linalg.qr(rng.standard_normal((N, K)))[0],
                    jnp.float64)
    a = rng.standard_normal(N)
    b = rng.standard_normal(N)
    V = jnp.asarray(np.stack([a, 0.5 * a, b], axis=1))  # col 1 dependent
    eps = float(np.finfo(np.float64).eps)
    scale = float(np.max(np.linalg.norm(np.asarray(V), axis=0)))
    P, oks, rnorms, n_passes = panel_imgs_orthogonalize(
        V, Q, thresh=50.0 * eps * scale)
    assert list(np.asarray(oks)) == [True, False, True]
    P = np.asarray(P)
    assert np.all(P[:, 1] == 0.0)
    G = np.concatenate([np.asarray(Q), P[:, [0, 2]]], axis=1)
    defect = np.abs(G.T @ G - np.eye(K + 2)).max()
    assert defect < dtype_tol(np.float64, N)
    assert np.all(np.asarray(n_passes) >= 1)
    # the dependent candidate's recorded residual is rounding noise
    assert float(rnorms[1]) < 50.0 * eps * scale


def test_block_step_single_sweep_flops():
    """One block step's FLOPs ~ p x (one matvec sweep), not p sweeps of
    everything (the fusion is in the (p,N)x(N,M) update)."""
    N, M = 512, 4096
    S = jax.ShapeDtypeStruct((N, M), jnp.float32)
    st = jax.eval_shape(lambda: greedy_init(jnp.zeros((N, M), jnp.float32),
                                            64))
    def flops(p):
        c = (jax.jit(lambda s, t: block_greedy_step(s, t, p=p))
             .lower(S, st).compile().cost_analysis())
        if isinstance(c, list):
            c = c[0]
        return float(c.get("flops", 0))
    f1, f4 = flops(1), flops(4)
    assert f4 < 4.6 * f1  # near-linear in p (no redundant sweeps)
