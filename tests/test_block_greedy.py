"""Block RB-greedy (beyond-paper §Perf): quality + cost properties.

Block builds run through the front door
(``build_basis(strategy="block_greedy")``; the direct ``rb_greedy_block``
entry point is deprecated).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_smooth_matrix
from repro.api import build_basis
from repro.core import rb_greedy
from repro.core.block_greedy import block_greedy_step
from repro.core.errors import orthogonality_defect, proj_error_max
from repro.core.greedy import greedy_init


def block_front_door(S, tau, p):
    return build_basis(source=S, strategy="block_greedy", tau=tau,
                       block_p=p)


@pytest.fixture(scope="module")
def gw_S():
    from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid
    f = frequency_grid(20.0, 512.0, 600)
    m1, m2 = chirp_grid(n_mc=32, n_eta=8)
    return build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128)


@pytest.mark.parametrize("p", [2, 4, 8])
def test_block_greedy_meets_tau(gw_S, p):
    tau = 1e-5
    res = block_front_door(gw_S, tau=tau, p=p)
    Q = res.Q
    assert float(proj_error_max(gw_S, Q)) < tau
    assert float(orthogonality_defect(Q)) < 1e-10


@pytest.mark.parametrize("p", [2, 4])
def test_block_greedy_basis_count_near_plain(gw_S, p):
    """Pivot staleness costs at most ~15% extra bases on smooth families."""
    tau = 1e-5
    k_plain = int(rb_greedy(gw_S, tau=tau).k)
    k_block = block_front_door(gw_S, tau=tau, p=p).k
    assert k_block <= int(k_plain * 1.15) + p


def test_block_p1_matches_plain():
    S = jnp.asarray(make_smooth_matrix())
    tau = 1e-6
    plain = rb_greedy(S, tau=tau)
    blk = block_front_door(S, tau=tau, p=1)
    kp, kb = int(plain.k), blk.k
    assert abs(kp - kb) <= 1
    k = min(kp, kb)
    assert np.array_equal(np.asarray(plain.pivots[:k]),
                          np.asarray(blk.pivots[:k]))


def test_block_step_single_sweep_flops():
    """One block step's FLOPs ~ p x (one matvec sweep), not p sweeps of
    everything (the fusion is in the (p,N)x(N,M) update)."""
    N, M = 512, 4096
    S = jax.ShapeDtypeStruct((N, M), jnp.float32)
    st = jax.eval_shape(lambda: greedy_init(jnp.zeros((N, M), jnp.float32),
                                            64))
    def flops(p):
        c = (jax.jit(lambda s, t: block_greedy_step(s, t, p=p))
             .lower(S, st).compile().cost_analysis())
        if isinstance(c, list):
            c = c[0]
        return float(c.get("flops", 0))
    f1, f4 = flops(1), flops(4)
    assert f4 < 4.6 * f1  # near-linear in p (no redundant sweeps)
