"""Chunked device-resident rb_greedy == the seed per-step driver.

The chunked driver runs C iterations inside one jitted lax.while_loop and
syncs only (n_done, stop_code) per chunk; these tests assert it matches
:func:`rb_greedy_stepwise` pivot-for-pivot including the rank-guard drop,
the tau-drop and the refresh path, across chunk sizes and dtypes.
"""

import subprocess
import sys
import os

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dtype_tol, make_smooth_matrix
from repro.core import rb_greedy, rb_greedy_stepwise


def _assert_same(a, b):
    ka, kb = int(a.k), int(b.k)
    assert ka == kb
    assert np.array_equal(np.asarray(a.pivots), np.asarray(b.pivots))
    # dtype-scaled (eps * sqrt(N)) comparison, not hard-coded ULP
    # constants: both drivers run the same kernels but float reduction
    # order may differ across XLA versions / fusion decisions.
    tol = dtype_tol(np.asarray(a.Q).dtype, n=a.Q.shape[0], factor=100.0)
    errscale = float(np.max(np.asarray(a.errs))) + 1e-300
    np.testing.assert_allclose(np.asarray(a.errs), np.asarray(b.errs),
                               rtol=tol, atol=tol * errscale)
    np.testing.assert_allclose(np.asarray(a.Q), np.asarray(b.Q),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(a.rnorms), np.asarray(b.rnorms),
                               rtol=tol, atol=tol * errscale)


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
@pytest.mark.parametrize("chunk", [1, 3, 16, 64])
@pytest.mark.parametrize("tau", [1e-4, 1e-8])
def test_matches_stepwise(dtype, chunk, tau):
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    _assert_same(rb_greedy_stepwise(S, tau=tau),
                 rb_greedy(S, tau=tau, chunk=chunk))


@pytest.mark.parametrize("chunk", [1, 5, 16])
def test_tau_drop_edge(chunk):
    """tau hit mid-chunk: the below-tau basis is dropped exactly like the
    seed driver (k, zeroed Q column/R row, pivot = -1)."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    a = rb_greedy_stepwise(S, tau=1e-6)
    b = rb_greedy(S, tau=1e-6, chunk=chunk)
    _assert_same(a, b)
    k = int(b.k)
    assert int(b.pivots[k]) == -1  # dropped slot marker
    assert float(jnp.linalg.norm(b.Q[:, k])) == 0.0


@pytest.mark.parametrize("chunk", [1, 4, 32])
def test_rank_guard_edge(chunk):
    """Exactly-low-rank snapshots: the junk pivot is dropped, not added."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((50, 8)) @ rng.standard_normal((8, 30))
    S = jnp.asarray(A)
    a = rb_greedy_stepwise(S, tau=1e-18)
    b = rb_greedy(S, tau=1e-18, chunk=chunk)
    _assert_same(a, b)
    assert int(b.k) <= 9  # stopped at numerical rank, no junk directions


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
@pytest.mark.parametrize("chunk", [2, 16])
def test_refresh_path(dtype, chunk):
    """Deep tolerance exercises the refresh stop-code round trip."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    a = rb_greedy_stepwise(S, tau=1e-12)
    b = rb_greedy(S, tau=1e-12, chunk=chunk)
    _assert_same(a, b)
    from repro.core.errors import proj_error_max
    assert float(proj_error_max(S, b.Q[:, :int(b.k)])) < 1e-11


def test_refresh_never_matches(chunk=7):
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    _assert_same(rb_greedy_stepwise(S, tau=1e-8, refresh="never"),
                 rb_greedy(S, tau=1e-8, chunk=chunk, refresh="never"))


def test_callback_per_chunk():
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    ref = rb_greedy_stepwise(S, tau=1e-8)
    k = int(ref.k)

    seen = []
    rb_greedy(S, tau=1e-8, chunk=4, callback=lambda s: seen.append(int(s.k)))
    # once per chunk, strictly increasing, history arrays complete at each
    assert seen == sorted(seen)
    assert len(seen) <= -(-(k + 1) // 4) + 2
    # chunk=1 restores the seed per-iteration cadence
    seen1 = []
    rb_greedy(S, tau=1e-8, chunk=1, callback=lambda s: seen1.append(int(s.k)))
    assert seen1 == list(range(1, seen1[-1] + 1))
    assert len(seen1) == k + 1  # k accepted + the dropped below-tau step


def test_callback_history_is_complete():
    """The per-chunk state carries the full per-step history (errs,
    pivots, rnorms) — what the seed driver exposed per iteration."""
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    hist = {}

    def cb(state):
        k = int(state.k)
        hist[k] = (np.asarray(state.errs[:k]).copy(),
                   np.asarray(state.pivots[:k]).copy())

    res = rb_greedy(S, tau=1e-8, chunk=8, callback=cb)
    k = int(res.k)
    last = hist[max(hist)]
    ref = rb_greedy_stepwise(S, tau=1e-8)
    np.testing.assert_allclose(last[0][:k], np.asarray(ref.errs[:k]))
    assert np.array_equal(last[1][:k], np.asarray(ref.pivots[:k]))


def test_invalid_chunk_rejected():
    S = jnp.asarray(make_smooth_matrix(dtype=np.float64))
    with pytest.raises(ValueError, match="chunk"):
        rb_greedy(S, tau=1e-4, chunk=0)


_DIST_SCRIPT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh
from repro.core import rb_greedy_stepwise
from repro.core.distributed import distributed_greedy

x = np.linspace(0, 1, 200)
nu = np.linspace(0.5, 2.0, 120)
S = np.stack([np.sin(2*np.pi*v*x)*np.exp(-v*x) for v in nu], axis=1)
S = jnp.asarray(S * np.exp(1j*np.outer(x, nu)))

ser = rb_greedy_stepwise(S, tau=1e-5)
k = int(ser.k)
mesh = Mesh(np.asarray(jax.devices()), ("cols",))
out = {"n_devices": len(jax.devices())}
for chunk in (1, 8):
    d = distributed_greedy(S, tau=1e-5, max_k=min(*S.shape), mesh=mesh,
                           chunk=chunk)
    kd = int(d.k)
    out[f"chunk{chunk}"] = {
        "k_serial": k, "k_dist": kd,
        "pivots_equal": bool(np.array_equal(np.asarray(ser.pivots[:k]),
                                            np.asarray(d.pivots[:kd]))),
        "max_err_diff": float(np.max(np.abs(
            np.asarray(ser.errs[:k]) - np.asarray(d.errs[:kd])))),
    }
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_chunk_result():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    import json
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("chunk", [1, 8])
def test_distributed_chunked_matches_serial(dist_chunk_result, chunk):
    r = dist_chunk_result[f"chunk{chunk}"]
    assert r["k_dist"] == r["k_serial"]
    assert r["pivots_equal"]
    assert r["max_err_diff"] < 1e-10
