"""Randomized sketch strategy: quality vs POD, one-pass streaming,
crash/resume bit-identity, greedy warm-start, and the HLO pins for the
sketch primitives.

The quality matrix asserts the randomized range-finder bound (Halko et
al., Thm. 10.5 in expectation): for a width-``ell = k + p`` Gaussian
sketch,

    E ||S - Q Q^H S||_F^2  <=  (1 + k/(p-1)) * sum_{j>k} sigma_j^2.

Seeds are FIXED (the test matrix is derived from counter-based keys), so
each asserted draw is deterministic; the bound is checked with a slack
factor that covers truncation-to-k and cross-backend summation-order
differences, plus a dtype floor for f32.
"""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_smooth_matrix
from repro.core import backend as B
from repro.core.randomized import rb_randomized_streamed
from repro.data.providers import (
    ArrayProvider,
    FaultPlan,
    FaultyProvider,
    MemmapProvider,
    WaveformProvider,
    write_snapshot_npy,
)


def _proj_err_fro(S, Q):
    S = np.asarray(S, np.complex128 if np.iscomplexobj(S) else np.float64)
    Q = np.asarray(Q, S.dtype)
    E = S - Q @ (Q.conj().T @ S)
    return float(np.linalg.norm(E))


def _pod_tail(S, k):
    sig = np.linalg.svd(
        np.asarray(S, np.complex128 if np.iscomplexobj(S) else np.float64),
        compute_uv=False)
    return float(np.sqrt(np.sum(sig[k:] ** 2))), sig


def _assert_range_finder_bound(S, res, max_k, sketch_p, slack=4.0):
    tail, sig = _pod_tail(S, max_k)
    err = _proj_err_fro(S, res.Q)
    bound = math.sqrt(1.0 + max_k / (sketch_p - 1)) * tail
    # dtype floor: at f32 the projection error cannot beat rounding on S
    eps = np.finfo(np.asarray(res.Q).real.dtype).eps
    floor = 100.0 * eps * float(np.linalg.norm(sig))
    assert err <= slack * bound + floor, (
        f"sketch Frobenius error {err:.3e} exceeds "
        f"{slack}x range-finder bound {bound:.3e} (+floor {floor:.1e})"
    )


# ------------------------------------------------- quality vs exact POD ----


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("provider", ["array", "memmap"])
def test_sketch_quality_matrix(tmp_path, dtype, provider):
    """{f32, c64} x {array, memmap}: one-pass sketch error within the
    (1 + k/(p-1)) range-finder bound of the exact POD tail."""
    S = make_smooth_matrix(200, 120, dtype=dtype)
    if provider == "memmap":
        src = MemmapProvider(write_snapshot_npy(tmp_path / "S.npy", S))
    else:
        src = ArrayProvider(jnp.asarray(S))
    res = rb_randomized_streamed(src, tau=None, max_k=15, sketch_p=10,
                                 tile_m=32)
    assert res.k == 15 and res.ell == 25 and res.n_passes == 1
    Q = np.asarray(res.Q)
    assert Q.dtype == np.dtype(dtype)
    # orthonormal basis
    G = Q.conj().T @ Q
    assert np.abs(G - np.eye(res.k)).max() < 1e-4
    _assert_range_finder_bound(S, res, max_k=15, sketch_p=10)
    # the free rider: exact column norms from the same pass
    np.testing.assert_allclose(
        res.norms_sq, np.sum(np.abs(S) ** 2, axis=0), rtol=1e-4)


def test_sketch_quality_waveform():
    """Waveform provider (columns generated on the fly): same bound."""
    from repro.gw import chirp_grid, frequency_grid

    f = frequency_grid(20.0, 256.0, 200)
    m1, m2 = chirp_grid(n_mc=11, n_eta=7)
    prov = WaveformProvider(f, m1, m2, dtype=jnp.complex64)
    S = np.asarray(prov.tile(0, prov.shape[1]))
    res = rb_randomized_streamed(prov, tau=None, max_k=12, sketch_p=10,
                                 tile_m=16)
    assert res.n_passes == 1
    _assert_range_finder_bound(S, res, max_k=12, sketch_p=10)


def test_power_iteration_sharpens_sigma_estimates():
    """power>=1 applies S to an orthonormal co-range, so the sketch's
    singular values are Ritz values: they must approximate the true
    spectrum far better than the power=0 sqrt(ell)-scaled estimates, and
    the projection error must not degrade."""
    S = make_smooth_matrix(200, 120, dtype=np.float64)
    sig = np.linalg.svd(S, compute_uv=False)
    r0 = rb_randomized_streamed(S, tau=None, max_k=15, sketch_p=10,
                                tile_m=40)
    r1 = rb_randomized_streamed(S, tau=None, max_k=15, sketch_p=10,
                                power=1, tile_m=40)
    assert r1.n_passes == 3
    np.testing.assert_allclose(r1.svals[:10], sig[:10], rtol=1e-3)
    e0 = np.abs(r0.svals[:10] - sig[:10]) / sig[:10]
    e1 = np.abs(r1.svals[:10] - sig[:10]) / sig[:10]
    assert e1.max() < e0.max()
    assert _proj_err_fro(S, r1.Q) <= 2.0 * _proj_err_fro(S, r0.Q)


def test_tau_rank_selection_matches_pod_criterion():
    """tau selects k = #{sigma_hat >= tau} (Algorithm 1's criterion on
    the estimates), capped at max_k."""
    S = make_smooth_matrix(200, 120, dtype=np.float64)
    res = rb_randomized_streamed(S, tau=1e-3, max_k=60, sketch_p=10,
                                 power=1, tile_m=40)
    assert res.k == int(np.sum(res.svals >= 1e-3))
    assert res.k < 60  # tau actually truncated
    capped = rb_randomized_streamed(S, tau=1e-3, max_k=5, sketch_p=10,
                                    power=1, tile_m=40)
    assert capped.k == 5


def test_rademacher_kind_same_bound():
    S = make_smooth_matrix(200, 120, dtype=np.complex64)
    res = rb_randomized_streamed(S, tau=None, max_k=15, sketch_p=10,
                                 tile_m=32, kind="rademacher")
    _assert_range_finder_bound(S, res, max_k=15, sketch_p=10)


# ---------------------------------------------- streaming / determinism ----


def test_one_streamed_pass_read_counter():
    """Acceptance: strategy builds the basis in ONE pass over the
    provider at power=0 (exactly n_tiles tile reads), 1 + 2*power passes
    otherwise."""
    S = make_smooth_matrix(200, 120, dtype=np.float32)
    n_tiles = math.ceil(120 / 32)
    prov = FaultyProvider(ArrayProvider(jnp.asarray(S)), FaultPlan())
    rb_randomized_streamed(prov, tau=None, max_k=15, tile_m=32)
    assert prov.reads == n_tiles
    prov2 = FaultyProvider(ArrayProvider(jnp.asarray(S)), FaultPlan())
    rb_randomized_streamed(prov2, tau=None, max_k=15, tile_m=32, power=2)
    assert prov2.reads == 5 * n_tiles


def test_sketch_deterministic_and_seeded():
    """Counter-derived test blocks: same seed -> bit-identical basis;
    different seed -> a different (but equally valid) draw."""
    S = make_smooth_matrix(200, 120, dtype=np.complex64)
    a = rb_randomized_streamed(S, tau=None, max_k=10, tile_m=32, seed=3)
    b = rb_randomized_streamed(S, tau=None, max_k=10, tile_m=32, seed=3)
    assert np.array_equal(np.asarray(a.Q), np.asarray(b.Q))
    assert np.array_equal(a.svals, b.svals)
    c = rb_randomized_streamed(S, tau=None, max_k=10, tile_m=32, seed=4)
    assert not np.array_equal(np.asarray(a.Q), np.asarray(c.Q))


@pytest.mark.parametrize("power,raise_at", [(0, 2), (1, 9)])
def test_mid_sketch_crash_resume_bit_identity(tmp_path, power, raise_at):
    """Kill the pass mid-phase (power=1 case dies inside a POWER pass);
    resume regenerates the remaining counter-derived blocks and lands on
    the uninterrupted run's bits."""
    S = make_smooth_matrix(200, 120, dtype=np.complex64)
    ref = rb_randomized_streamed(S, tau=None, max_k=12, sketch_p=6,
                                 power=power, tile_m=16)
    d = str(tmp_path / "ckpt")
    prov = FaultyProvider(ArrayProvider(jnp.asarray(S)),
                          FaultPlan(raise_at_tile=raise_at))
    with pytest.raises(IOError):
        rb_randomized_streamed(prov, tau=None, max_k=12, sketch_p=6,
                               power=power, tile_m=16, checkpoint_dir=d,
                               checkpoint_every_tiles=2)
    res = rb_randomized_streamed(S, tau=None, max_k=12, sketch_p=6,
                                 power=power, tile_m=16, checkpoint_dir=d,
                                 resume=True)
    assert np.array_equal(np.asarray(res.Q), np.asarray(ref.Q))
    assert np.array_equal(res.svals, ref.svals)
    assert np.array_equal(res.norms_sq, ref.norms_sq)


def test_resume_validates_checkpoint_compatibility(tmp_path):
    """A resumed pass must replay the same tiling/width/test matrix (the
    cursor is in tile units, Omega blocks are per-(seed, tile)); any
    drift is a hard error, not silent corruption."""
    S = make_smooth_matrix(100, 60, dtype=np.float32)
    d = str(tmp_path / "ckpt")
    prov = FaultyProvider(ArrayProvider(jnp.asarray(S)),
                          FaultPlan(raise_at_tile=2))
    with pytest.raises(IOError):
        rb_randomized_streamed(prov, tau=None, max_k=8, sketch_p=4,
                               tile_m=16, checkpoint_dir=d,
                               checkpoint_every_tiles=1)
    common = dict(tau=None, checkpoint_dir=d, resume=True)
    with pytest.raises(ValueError, match="tile_m"):
        rb_randomized_streamed(S, max_k=8, sketch_p=4, tile_m=20, **common)
    with pytest.raises(ValueError, match="width"):
        rb_randomized_streamed(S, max_k=9, sketch_p=4, tile_m=16, **common)
    with pytest.raises(ValueError, match="test-matrix"):
        rb_randomized_streamed(S, max_k=8, sketch_p=4, tile_m=16, seed=1,
                               **common)
    with pytest.raises(ValueError, match="test-matrix"):
        rb_randomized_streamed(S, max_k=8, sketch_p=4, tile_m=16,
                               kind="rademacher", **common)
    # a partial Y carries one backend's summation order: resuming under
    # the OTHER backend must refuse (CI runs both matrix legs, so pick
    # whichever is not the currently-resolved one)
    other = "xla" if B.resolve_backend(None) == "xla_ref" else "xla_ref"
    with pytest.raises(ValueError, match="backend"):
        rb_randomized_streamed(S, max_k=8, sketch_p=4, tile_m=16,
                               backend=other, **common)


def test_argument_validation():
    S = make_smooth_matrix(50, 30, dtype=np.float32)
    with pytest.raises(ValueError, match="sketch_p"):
        rb_randomized_streamed(S, tau=None, sketch_p=-1)
    with pytest.raises(ValueError, match="power"):
        rb_randomized_streamed(S, tau=None, power=-1)
    with pytest.raises(ValueError, match="kind"):
        rb_randomized_streamed(S, tau=None, kind="srht")
    with pytest.raises(ValueError, match="resume"):
        rb_randomized_streamed(S, tau=None, resume=True)


# ------------------------------------------------------- HLO pins ----------


def _dot_lines(hlo_text):
    return [l for l in hlo_text.splitlines() if "dot" in l]


@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_sketch_primitives_no_complex_dot(rng, dtype):
    """The sketch fold/project must lower to REAL dot ops under the xla
    backend (plane-split 4-GEMM plan) — the same structural pin every
    other hot primitive carries (complex dots lower to a ~10x scalar
    loop on CPU XLA)."""
    N, M, L = 64, 48, 12
    T = jnp.asarray((rng.standard_normal((N, M))
                     + 1j * rng.standard_normal((N, M))).astype(dtype))
    Om = jnp.asarray((rng.standard_normal((M, L))
                      + 1j * rng.standard_normal((M, L))).astype(dtype))
    Y = jnp.zeros((N, L), dtype)

    def lower_fold(bk):
        return jax.jit(
            lambda *a: B.sketch_fold(*a, backend=bk)
        ).lower(T, Om, Y).as_text()

    dots = _dot_lines(lower_fold("xla"))
    assert dots and not any("complex" in l for l in dots)
    assert any("complex" in l for l in _dot_lines(lower_fold("xla_ref")))

    def lower_proj(bk):
        return jax.jit(
            lambda *a: B.sketch_project(*a, backend=bk)
        ).lower(T, Y).as_text()

    dots = _dot_lines(lower_proj("xla"))
    assert dots and not any("complex" in l for l in dots)
    assert any("complex" in l for l in _dot_lines(lower_proj("xla_ref")))


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_sketch_primitives_backend_parity(rng, dtype):
    """Plane-split and reference forms compute the same products."""
    N, M, L = 40, 30, 8
    mk = (lambda s: (rng.standard_normal(s)
                     + 1j * rng.standard_normal(s)).astype(dtype)
          if np.issubdtype(dtype, np.complexfloating)
          else rng.standard_normal(s).astype(dtype))
    T, Om, Y = mk((N, M)), mk((M, L)), mk((N, L))
    tol = 200 * np.finfo(np.dtype(dtype).type(0).real.dtype).eps
    np.testing.assert_allclose(
        np.asarray(B.sketch_fold(T, Om, Y, backend="xla")),
        np.asarray(B.sketch_fold(T, Om, Y, backend="xla_ref")),
        rtol=tol, atol=tol)
    np.testing.assert_allclose(
        np.asarray(B.sketch_project(T, Y, backend="xla")),
        np.asarray(B.sketch_project(T, Y, backend="xla_ref")),
        rtol=tol, atol=tol)


# ------------------------------------------- sketch + greedy refinement ----


def test_sketch_greedy_exact_low_rank_needs_no_refinement():
    """On an exactly rank-r family with ell >= r the sketch captures the
    range whole: greedy refinement must accept ZERO additional pivots
    (all pivots stay the sketch's -1 sentinel) and stop at tau."""
    from repro.api import build_basis
    from repro.core.errors import proj_error_max

    rng = np.random.default_rng(5)
    r = 8
    A = rng.standard_normal((200, r))
    Bm = rng.standard_normal((r, 120))
    S = (A @ Bm).astype(np.float64)
    basis = build_basis(source=S, strategy="sketch+greedy", tau=1e-8,
                        max_k=30, sketch_p=10, tile_m=32)
    assert basis.provenance["sketch"]["k0"] == basis.k
    assert np.all(np.asarray(basis.pivots) == -1)
    assert basis.provenance["stop"] == "STOP_TAU"
    assert float(proj_error_max(S, basis.Q)) < 1e-8


def test_sketch_greedy_refines_to_tau_parity_with_cold_greedy():
    """On a generic smooth family: refinement extends the sketch basis
    with real pivots until the SAME tau the cold streamed greedy reaches,
    and both bases meet it (error parity; the sketch start must not cost
    correctness)."""
    from repro.api import build_basis
    from repro.core.errors import proj_error_max

    S = make_smooth_matrix(200, 120, dtype=np.complex64)
    tau = 1e-4
    warm = build_basis(source=S, strategy="sketch+greedy", tau=tau,
                       max_k=60, sketch_p=5, tile_m=32,
                       sketch_power=1)
    cold = build_basis(source=S, strategy="streamed", tau=tau, max_k=60,
                       tile_m=32)
    assert float(proj_error_max(S, warm.Q)) < tau
    assert float(proj_error_max(S, cold.Q)) < tau
    k0 = warm.provenance["sketch"]["k0"]
    added = np.asarray(warm.pivots)[k0:]
    # refinement pivots are REAL column selections (the sketch's are -1)
    assert np.all(np.asarray(warm.pivots)[:k0] == -1)
    assert np.all(added >= 0)
    # the warm start cannot need more refinement sweeps than the cold
    # build needed bases in total
    assert warm.k - k0 <= cold.k


# ---------------------------------------------------------- front door -----


def test_front_door_randomized_strategy():
    """build_basis(strategy="randomized"): POD-shaped artifact (no
    pivots), sketch provenance (params + sigma estimates), per-column
    error consistent with the basis."""
    from repro.api import build_basis

    S = make_smooth_matrix(200, 120, dtype=np.complex64)
    basis = build_basis(source=S, strategy="randomized", tau=1e-4,
                        max_k=40, tile_m=32, sketch_power=1)
    assert basis.pivots.shape == (0,)
    sk = basis.provenance["sketch"]
    assert sk["p"] == 10 and sk["power"] == 1 and sk["n_passes"] == 3
    assert sk["kind"] == "gaussian" and sk["ell"] == 50
    est = basis.provenance["sigma_estimates"]
    assert len(est) == sk["ell"] and est == sorted(est, reverse=True)
    assert len(basis.errs) == basis.k
    assert float(basis.per_column_errors(S).max()) < 1e-3


def test_front_door_randomized_workdir_resume(tmp_path):
    """The PR-6 workdir lifecycle composes: a fresh randomized build
    finalizes into the workdir, and a resume relaunch returns the
    finalized artifact bit-identically."""
    from repro.api import ReducedBasis, build_basis

    S = make_smooth_matrix(200, 120, dtype=np.float32)
    wd = str(tmp_path / "wd")
    built = build_basis(source=S, strategy="randomized", tau=None,
                        max_k=20, tile_m=32, workdir=wd)
    again = build_basis(source=S, strategy="randomized", tau=None,
                        max_k=20, tile_m=32, workdir=wd, resume=True)
    assert np.array_equal(np.asarray(built.Q), np.asarray(again.Q))
    assert not os.path.exists(os.path.join(wd, "build"))
    loaded = ReducedBasis.load(wd)
    assert loaded.provenance["sketch"] == built.provenance["sketch"]


def test_auto_picks_randomized_when_sketch_passes_win():
    """Roof-bound sweep + a rank target whose greedy pass count exceeds
    2x the sketch's -> "auto" resolves to the one-pass range-finder; with
    no max_k (unbounded sketch width) and on-device probing disabled
    (the conftest's REPRO_ROOFLINE_MEASURE=0 also gates off sketch-based
    rank estimation) it must NOT."""
    from repro.api import ReductionSpec
    from repro.api.build import _auto_strategy

    roofs = dict(bandwidth_gbps=10.0, peak_gflops=1e4, cache_bytes=1)
    spec = ReductionSpec(source="unused", strategy="auto", max_k=64,
                         **roofs)
    choice, block_p, _k = _auto_strategy(spec, (4096, 16384), jnp.float32)
    assert choice == "randomized"
    assert block_p == 1  # blocking is a greedy knob; not forced on
    # no rank target: the sketch width is unbounded -> stay greedy
    spec_nok = ReductionSpec(source="unused", strategy="auto", **roofs)
    choice, _, _k = _auto_strategy(spec_nok, (4096, 16384), jnp.float32)
    assert choice == "block_greedy"
    # rank target small enough that blocked greedy passes <= 2x sketch:
    # blocking wins
    spec_small = ReductionSpec(source="unused", strategy="auto", max_k=16,
                               **roofs)
    choice, _, _k = _auto_strategy(spec_small, (4096, 16384), jnp.float32)
    assert choice == "block_greedy"
    # deeper power iteration raises the sketch's pass bill: cutover moves
    spec_pow = ReductionSpec(source="unused", strategy="auto", max_k=64,
                             sketch_power=2, **roofs)
    choice, _, _k = _auto_strategy(spec_pow, (4096, 16384), jnp.float32)
    assert choice == "block_greedy"


# ------------------------------------------------ rank estimation (PR 9) ----


def test_estimate_rank_finds_numerical_rank():
    """A rank-r family with a noise floor below tau estimates ~r from a
    sketch far narrower than min(N, M), in one streamed pass."""
    from repro.core.randomized import estimate_rank

    r_ = np.random.default_rng(3)
    L = r_.standard_normal((256, 20)) @ r_.standard_normal((20, 400))
    L = L / np.abs(L).max()
    est = estimate_rank(jnp.asarray(L.astype(np.float32)), tau=1e-5)
    assert not est.saturated
    assert est.ell == 32 and est.passes == 1
    assert 18 <= est.k <= 22, est


def test_estimate_rank_doubles_until_unsaturated():
    """A rank past the initial width saturates the first sketch; the
    doubling loop widens until the spectrum tail appears."""
    from repro.core.randomized import estimate_rank

    r_ = np.random.default_rng(4)
    L = r_.standard_normal((256, 48)) @ r_.standard_normal((48, 400))
    L = L / np.abs(L).max()
    est = estimate_rank(jnp.asarray(L.astype(np.float32)), tau=1e-5,
                        ell0=16)
    assert not est.saturated
    assert est.ell == 64  # 16 -> 32 -> 64 before the tail showed
    assert est.passes == 3
    assert 44 <= est.k <= 52, est


def test_estimate_rank_reports_saturation_at_cap():
    from repro.core.randomized import estimate_rank

    r_ = np.random.default_rng(5)
    full = r_.standard_normal((64, 96)).astype(np.float32)  # full-rank
    est = estimate_rank(jnp.asarray(full), tau=1e-9, ell0=8, max_ell=16)
    assert est.saturated
    assert est.ell == 16 and est.k == 16


def test_auto_rank_estimation_enables_randomized_cutover(monkeypatch,
                                                         caplog):
    """The PR-7 follow-on: with no max_k, roof-bound, and probing enabled
    (REPRO_ROOFLINE_MEASURE=1), "auto" sketch-estimates a rank, caps
    max_k with headroom, and the pass-count comparison can now pick the
    range-finder; under the CI determinism knob (=0) the estimate never
    runs and the decision table is unchanged."""
    import logging

    from repro.api import ReductionSpec
    from repro.api.build import _auto_strategy

    r_ = np.random.default_rng(6)
    L = r_.standard_normal((256, 20)) @ r_.standard_normal((20, 512))
    S = jnp.asarray((L / np.abs(L).max()).astype(np.float32))
    roofs = dict(bandwidth_gbps=10.0, peak_gflops=1e4, cache_bytes=1)
    spec = ReductionSpec(source=S, strategy="auto", tau=1e-5, **roofs)

    monkeypatch.setenv("REPRO_ROOFLINE_MEASURE", "1")
    with caplog.at_level(logging.INFO, logger="repro.api"):
        choice, _, max_k = _auto_strategy(spec, S.shape, S.dtype)
    assert choice == "randomized"
    assert max_k is not None and max_k >= 20  # estimate + headroom
    assert any("sketch-estimated" in rec.getMessage()
               for rec in caplog.records)

    monkeypatch.setenv("REPRO_ROOFLINE_MEASURE", "0")
    choice, _, max_k = _auto_strategy(spec, S.shape, S.dtype)
    assert choice == "block_greedy"  # deterministic leg: no probing
    assert max_k is None
