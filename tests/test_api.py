"""The front door (repro.api): strategy parity, auto selection, artifact
save/load round trips, and deprecation hygiene of the legacy wrappers.

Acceptance contract of the API PR: ``build_basis`` with every strategy
returns a ReducedBasis whose Q/pivots/errs match the corresponding legacy
driver bit-for-bit, ``"auto"`` picks resident vs streamed vs distributed
correctly, and a saved basis reloads to working ``eim()``/``roq_weights()``.
"""

import logging
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_smooth_matrix
from repro.api import STRATEGIES, ReducedBasis, ReductionSpec, build_basis
from repro.core import pod, rb_greedy, rb_greedy_streamed
from repro.core.block_greedy import _rb_greedy_block_impl
from repro.core.mgs import _mgs_pivoted_qr_impl

TAU = 1e-3


def _S(dtype=np.complex64):
    return jnp.asarray(make_smooth_matrix(dtype=dtype))


def _assert_bitwise(basis, Q, pivots, errs, k):
    assert basis.k == k
    assert basis.Q.shape == (Q.shape[0], k)
    assert np.array_equal(np.asarray(basis.Q), np.asarray(Q))
    assert np.array_equal(basis.pivots, np.asarray(pivots))
    assert np.array_equal(basis.errs, np.asarray(errs))


# ------------------------------------------------------------ parity ----


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_greedy_strategy_matches_legacy(dtype):
    S = _S(dtype)
    basis = build_basis(source=S, strategy="greedy", tau=TAU)
    ref = rb_greedy(S, tau=TAU)
    k = int(ref.k)
    _assert_bitwise(basis, ref.Q[:, :k], ref.pivots[:k], ref.errs[:k], k)
    assert np.array_equal(np.asarray(basis.R), np.asarray(ref.R[:k]))


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_streamed_strategy_matches_legacy(dtype):
    S = _S(dtype)
    basis = build_basis(source=S, strategy="streamed", tau=TAU, tile_m=40)
    ref = rb_greedy_streamed(S, tau=TAU, tile_m=40)
    k = int(ref.k)
    _assert_bitwise(basis, ref.Q[:, :k], ref.pivots[:k], ref.errs[:k], k)


def test_mgs_strategy_matches_legacy():
    S = _S(np.complex128)
    basis = build_basis(source=S, strategy="mgs", tau=TAU)
    ref = _mgs_pivoted_qr_impl(S, tau=TAU)
    _assert_bitwise(basis, ref.Q, ref.pivots, ref.r_diag, int(ref.k))


def test_pod_strategy_matches_legacy():
    S = _S(np.complex64)
    basis = build_basis(source=S, strategy="pod", tau=TAU)
    ref = pod(S, tau=TAU)
    k = int(ref.k)
    _assert_bitwise(basis, ref.basis[:, :k], np.zeros((0,), np.int32),
                    ref.sigmas[:k], k)
    assert basis.R is None


def test_block_greedy_strategy_matches_legacy():
    S = _S(np.complex64)
    basis = build_basis(source=S, strategy="block_greedy", tau=TAU,
                        block_p=2)
    ref = _rb_greedy_block_impl(S, tau=TAU, p=2)
    k = int(ref.k)
    _assert_bitwise(basis, ref.Q[:, :k], ref.pivots[:k], ref.errs[:k], k)


def test_distributed_strategy_matches_legacy():
    """Single-device mesh in-process (the 8-device parity suite lives in
    test_distributed_greedy.py); the front door must hand back exactly
    what distributed_greedy produces."""
    from repro.compat import make_auto_mesh
    from repro.core.distributed import distributed_greedy

    S = _S(np.complex64)
    mesh = make_auto_mesh((1,), ("cols",))
    basis = build_basis(source=S, strategy="distributed", tau=TAU,
                        mesh=mesh)
    ref = distributed_greedy(S, tau=TAU, max_k=min(*S.shape), mesh=mesh)
    k = int(ref.k)
    _assert_bitwise(basis, ref.Q[:, :k], ref.pivots[:k], ref.errs[:k], k)


def test_distributed_requires_mesh():
    with pytest.raises(ValueError, match="mesh"):
        build_basis(source=_S(), strategy="distributed", tau=TAU)


# ----------------------------------------------------- auto selection ----


def test_auto_picks_resident_when_it_fits(caplog):
    S = _S(np.complex64)
    with caplog.at_level(logging.INFO, logger="repro.api"):
        basis = build_basis(source=S, tau=TAU)
    assert basis.provenance["strategy"] == "greedy"
    assert basis.provenance["requested_strategy"] == "auto"
    assert any("auto strategy" in r.getMessage() for r in caplog.records)
    ref = rb_greedy(S, tau=TAU)
    k = int(ref.k)
    _assert_bitwise(basis, ref.Q[:, :k], ref.pivots[:k], ref.errs[:k], k)


def test_auto_picks_streamed_on_forced_small_budget():
    S = _S(np.complex64)
    basis = build_basis(source=S, tau=TAU, memory_budget_bytes=1024,
                        tile_m=40)
    assert basis.provenance["strategy"] == "streamed"
    ref = rb_greedy_streamed(S, tau=TAU, tile_m=40)
    k = int(ref.k)
    _assert_bitwise(basis, ref.Q[:, :k], ref.pivots[:k], ref.errs[:k], k)


def test_auto_picks_distributed_with_mesh():
    from repro.compat import make_auto_mesh

    S = _S(np.complex64)
    basis = build_basis(source=S, tau=TAU,
                        mesh=make_auto_mesh((1,), ("cols",)))
    assert basis.provenance["strategy"] == "distributed"


def test_auto_respects_env_budget(monkeypatch):
    from repro.api.build import device_memory_budget

    monkeypatch.setenv("REPRO_DEVICE_MEM_BUDGET", "12345")
    assert device_memory_budget() == 12345


# --------------------------------------- auto DRAM-roofline (PR 4) ----


def test_auto_picks_block_greedy_on_roof_bound_shape():
    """Acceptance: the bandwidth model must select block_greedy for the
    paper benchmark's roof-bound f32 resident shape (N=4096, M=16384) —
    the shape whose committed BENCH rows sat BELOW 1x before blocking.
    Decision-level: the spec's source is never touched."""
    from repro.api.build import _auto_strategy

    spec = ReductionSpec(source="unused", strategy="auto")
    choice, block_p, _k = _auto_strategy(spec, (4096, 16384), jnp.float32)
    assert choice == "block_greedy"
    assert block_p > 1  # the model raised the stepwise default


def test_auto_block_greedy_end_to_end(caplog):
    """Forcing the roofline knobs makes a small matrix classify as
    roof-bound: auto must build THROUGH the blocked driver (logged),
    bit-identical to calling it directly."""
    from repro.core.block_greedy import _rb_greedy_block_impl

    S = _S(np.float32)
    with caplog.at_level(logging.INFO, logger="repro.api"):
        basis = build_basis(source=S, tau=TAU, block_p=2, cache_bytes=1)
    assert basis.provenance["strategy"] == "block_greedy"
    assert basis.provenance["requested_strategy"] == "auto"
    assert basis.provenance["block_p"] == 2
    assert any("roof-bound" in r.getMessage() for r in caplog.records)
    ref = _rb_greedy_block_impl(S, tau=TAU, p=2)
    k = int(ref.k)
    _assert_bitwise(basis, ref.Q[:, :k], ref.pivots[:k], ref.errs[:k], k)


def test_auto_blocked_streamed_when_too_big():
    """Too big for the budget AND roof-bound -> blocked-streamed: the
    block_p the model picked reaches the streamed driver."""
    from repro.core.streaming import rb_greedy_streamed

    S = _S(np.complex64)
    basis = build_basis(source=S, tau=TAU, memory_budget_bytes=1024,
                        tile_m=40, cache_bytes=1)
    assert basis.provenance["strategy"] == "streamed"
    assert basis.provenance["block_p"] > 1
    ref = rb_greedy_streamed(S, tau=TAU, tile_m=40,
                             block_p=basis.provenance["block_p"])
    k = int(ref.k)
    _assert_bitwise(basis, ref.Q[:, :k], ref.pivots[:k], ref.errs[:k], k)


def test_auto_roofline_env_overrides(monkeypatch):
    """REPRO_DRAM_BW_GBPS / REPRO_PEAK_GFLOPS / REPRO_LLC_BYTES feed the
    model; spec fields win over the env (and both win over any
    measurement, which pinned knobs skip entirely)."""
    from repro.api.build import machine_roofline

    monkeypatch.setenv("REPRO_DRAM_BW_GBPS", "10")
    monkeypatch.setenv("REPRO_PEAK_GFLOPS", "100")
    monkeypatch.setenv("REPRO_LLC_BYTES", "1000")
    monkeypatch.setenv("REPRO_ROOFLINE_MEASURE", "1")  # pinned knobs win
    assert machine_roofline(None) == (10.0, 100.0, 1000)
    spec = ReductionSpec(source="unused", bandwidth_gbps=5.0)
    assert machine_roofline(spec) == (5.0, 100.0, 1000)


# ------------------------------------- measured roofline (PR 5) ----


def test_roofline_measurement_disabled_by_default_in_tests(monkeypatch):
    """Under REPRO_ROOFLINE_MEASURE=0 (the conftest/CI default) the model
    falls back to the per-platform defaults — no measurement runs, so
    auto decisions stay deterministic on the noisy box."""
    import repro.api.roofline as R
    from repro.api.build import _PLATFORM_ROOFS, machine_roofline

    assert not R.roofline_measurement_enabled()
    monkeypatch.delenv("REPRO_DRAM_BW_GBPS", raising=False)
    monkeypatch.delenv("REPRO_PEAK_GFLOPS", raising=False)
    monkeypatch.delenv("REPRO_LLC_BYTES", raising=False)

    def boom():  # measurement must not even be consulted
        raise AssertionError("measured_roofline called despite opt-out")

    monkeypatch.setattr(R, "measured_roofline", boom)
    monkeypatch.setattr(R, "measured_cache_bytes", boom)
    bw, gf, cache = machine_roofline(None)
    assert (bw, gf, cache) == _PLATFORM_ROOFS["cpu"]


def test_measured_roofline_feeds_model_when_enabled(monkeypatch, caplog):
    """REPRO_ROOFLINE_MEASURE=1 with no pinned knobs: the one-time
    on-device calibration fills bandwidth/FLOPs (positive, finite,
    logged) AND the LLC knob (the PR-9 working-set sweep — stubbed here;
    its own tests exercise the measurement).  Cached per process: the
    second model call must not re-measure."""
    import repro.api.roofline as R
    from repro.api.build import machine_roofline

    monkeypatch.setenv("REPRO_ROOFLINE_MEASURE", "1")
    monkeypatch.delenv("REPRO_DRAM_BW_GBPS", raising=False)
    monkeypatch.delenv("REPRO_PEAK_GFLOPS", raising=False)
    monkeypatch.delenv("REPRO_LLC_BYTES", raising=False)
    monkeypatch.setattr(R, "measured_cache_bytes", lambda: 48 << 20)
    R.measured_roofline.cache_clear()
    with caplog.at_level(logging.INFO, logger="repro.api"):
        bw, gf, cache = machine_roofline(None)
    assert np.isfinite(bw) and bw > 0
    assert np.isfinite(gf) and gf > 0
    assert cache == 48 << 20  # the measured LLC fed the model
    assert any("measured roofline" in r.getMessage()
               for r in caplog.records)
    assert machine_roofline(None) == (bw, gf, cache)  # stable re-read
    info = R.measured_roofline.cache_info()
    assert info.currsize == 1 and info.hits >= 1  # measured exactly once


def test_measured_roofline_failure_not_cached(monkeypatch):
    """Regression (PR-7 bugfix): a transient calibration failure used to
    be lru_cached as the (0.0, 0.0) sentinel, permanently disabling
    measured roofs for the process.  The failure path must NOT be cached
    — the next call retries and a later success IS cached."""
    import repro.api.roofline as R

    R.measured_roofline.cache_clear()
    calls = {"n": 0}
    real_steady = R._steady_min

    def flaky_steady(fn, *a, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected transient calibration failure")
        return real_steady(fn, repeats=1, warmup=0)

    monkeypatch.setattr(R, "_steady_min", flaky_steady)
    try:
        assert R.measured_roofline() == (0.0, 0.0)  # sentinel reported...
        assert R.measured_roofline.cache_info().currsize == 0  # ...UNCACHED
        bw, gf = R.measured_roofline()  # retried -> real measurement
        assert bw > 0 and gf > 0
        assert R.measured_roofline() == (bw, gf)
        info = R.measured_roofline.cache_info()
        assert info.currsize == 1 and info.hits >= 1  # success cached
    finally:
        R.measured_roofline.cache_clear()  # drop the 1-repeat numbers


def test_auto_decision_table_deterministic_without_measurement():
    """CI acceptance: under REPRO_ROOFLINE_MEASURE=0 the auto-strategy
    decision table reproduces the PR-4 classifications from the
    per-platform default roofs — the matrix legs stay deterministic."""
    import os

    from repro.api.build import _auto_strategy

    assert os.environ.get("REPRO_ROOFLINE_MEASURE") == "0"  # conftest
    spec = ReductionSpec(source="unused", strategy="auto")
    # the paper benchmark's roof-bound resident shapes (PR-4 table)
    for dtype in (jnp.float32, jnp.complex64):
        choice, block_p, _k = _auto_strategy(spec, (4096, 16384), dtype)
        assert choice == "block_greedy"
        assert block_p == 8
    # small, cache-resident shape: stepwise resident greedy
    choice, block_p, _k = _auto_strategy(spec, (200, 120), jnp.float32)
    assert choice == "greedy"
    assert block_p == 1
    # explicit block_p is respected, not overridden
    spec_p = ReductionSpec(source="unused", strategy="auto", block_p=3)
    choice, block_p, _k = _auto_strategy(spec_p, (4096, 16384), jnp.float32)
    assert choice == "block_greedy"
    assert block_p == 3


# ------------------------- panel ortho / adaptive block_p (PR 5) ----


def test_front_door_panel_ortho_flag_reaches_driver():
    """panel_ortho=False must route the blocked build through the
    p-sequential ortho path — bit-identical to calling the driver with
    panel=False directly (and distinct plumbing from the default)."""
    S = _S(np.complex64)
    basis = build_basis(source=S, strategy="block_greedy", tau=TAU,
                        block_p=4, panel_ortho=False)
    ref = _rb_greedy_block_impl(S, tau=TAU, p=4, panel=False)
    k = int(ref.k)
    _assert_bitwise(basis, ref.Q[:, :k], ref.pivots[:k], ref.errs[:k], k)


def test_adaptive_block_records_p_trajectory():
    """adaptive_block=True: the live panel width is bounded by the spec's
    block_p, the trajectory lands in the provenance (JSON-serializable),
    and the build still reaches tau."""
    import json

    from repro.core.errors import proj_error_max

    S = _S(np.complex64)
    basis = build_basis(source=S, strategy="block_greedy", tau=TAU,
                        block_p=8, adaptive_block=True)
    traj = basis.provenance["p_trajectory"]
    assert isinstance(traj, list) and traj
    json.dumps(traj)  # provenance must stay JSON-serializable
    assert all(1 <= entry["p"] <= 8 for entry in traj)
    assert traj[0]["p"] == 8  # starts at the spec ceiling
    # the rejection signal actually fired on this family: the width moved
    assert any(entry["p"] < 8 for entry in traj)
    assert float(proj_error_max(S, basis.Q)) < TAU
    # non-adaptive builds carry no trajectory
    plain = build_basis(source=S, strategy="block_greedy", tau=TAU,
                        block_p=8)
    assert "p_trajectory" not in plain.provenance


def test_distributed_block_p_routes_to_blocked_driver():
    """block_p > 1 on a mesh runs the blocked distributed sweep; a
    1-device mesh must reproduce the resident blocked driver."""
    from repro.compat import make_auto_mesh
    from repro.core.block_greedy import _rb_greedy_block_impl

    S = _S(np.complex64)
    basis = build_basis(source=S, strategy="distributed", tau=TAU,
                        mesh=make_auto_mesh((1,), ("cols",)), block_p=2)
    ref = _rb_greedy_block_impl(S, tau=TAU, p=2)
    k = int(ref.k)
    assert basis.k == k
    assert np.array_equal(basis.pivots, np.asarray(ref.pivots[:k]))


# --------------------------------------------------- source coercion ----


def test_same_source_works_across_strategies(tmp_path):
    """Satellite: a .npy path (or provider) is a valid source for EVERY
    strategy, not only the streamed one."""
    from repro.data import ArrayProvider, write_snapshot_npy

    S_host = make_smooth_matrix(dtype=np.complex64)
    path = write_snapshot_npy(tmp_path / "S.npy", S_host)
    S = jnp.asarray(S_host)

    for strategy in ("greedy", "mgs", "pod", "block_greedy", "streamed"):
        from_path = build_basis(source=path, strategy=strategy, tau=TAU)
        from_prov = build_basis(source=ArrayProvider(S), strategy=strategy,
                                tau=TAU)
        from_array = build_basis(source=S, strategy=strategy, tau=TAU)
        for other in (from_prov, from_array):
            assert from_path.k == other.k, strategy
            assert np.array_equal(np.asarray(from_path.Q),
                                  np.asarray(other.Q)), strategy


def test_numpy_source_stays_host_resident_until_tiled():
    """ArrayProvider must not device-place a host matrix at wrap time:
    "auto" probes shape/dtype through as_provider BEFORE deciding, and a
    too-big-for-device source must still be able to pick "streamed"."""
    from repro.data import ArrayProvider, as_provider

    S_host = make_smooth_matrix(dtype=np.complex64)
    prov = as_provider(S_host)
    assert isinstance(prov, ArrayProvider)
    assert isinstance(prov._S, np.ndarray)  # no eager device transfer
    t = prov.tile(0, 7)
    assert isinstance(t, jax.Array)
    assert np.array_equal(np.asarray(t), S_host[:, :7])

    # a raw numpy source streams tile-by-tile and matches the jax source
    a = build_basis(source=S_host, strategy="streamed", tau=TAU, tile_m=40)
    b = build_basis(source=jnp.asarray(S_host), strategy="streamed",
                    tau=TAU, tile_m=40)
    assert np.array_equal(np.asarray(a.Q), np.asarray(b.Q))


def test_legacy_drivers_accept_coerced_sources(tmp_path):
    """rb_greedy / mgs / pod accept what as_provider accepts, directly."""
    from repro.data import write_snapshot_npy

    S_host = make_smooth_matrix(dtype=np.complex64)
    path = write_snapshot_npy(tmp_path / "S.npy", S_host)
    S = jnp.asarray(S_host)

    ref = rb_greedy(S, tau=TAU)
    got = rb_greedy(path, tau=TAU)
    assert np.array_equal(np.asarray(got.Q), np.asarray(ref.Q))

    assert np.array_equal(
        np.asarray(pod(path, tau=TAU).basis),
        np.asarray(pod(S, tau=TAU).basis),
    )
    m_ref = _mgs_pivoted_qr_impl(S, tau=TAU)
    m_got = _mgs_pivoted_qr_impl(path, tau=TAU)
    assert np.array_equal(np.asarray(m_got.Q), np.asarray(m_ref.Q))


# -------------------------------------------------- spec ergonomics ----


def test_spec_and_kwargs_equivalent():
    S = _S()
    spec = ReductionSpec(source=S, strategy="greedy", tau=TAU)
    a = build_basis(spec)
    b = build_basis(source=S, strategy="greedy", tau=TAU)
    c = build_basis(spec, tau=TAU)  # kwargs override path
    assert np.array_equal(np.asarray(a.Q), np.asarray(b.Q))
    assert np.array_equal(np.asarray(a.Q), np.asarray(c.Q))


def test_spec_validation():
    with pytest.raises(ValueError, match="strategy"):
        ReductionSpec(source=np.ones((2, 2)), strategy="nope")
    with pytest.raises(ValueError, match="source"):
        ReductionSpec()
    with pytest.raises(TypeError):
        build_basis(np.ones((2, 2)))  # a bare matrix is not a spec


def test_provenance_fields():
    S = _S()
    basis = build_basis(source=S, strategy="greedy", tau=TAU)
    p = basis.provenance
    assert p["shape"] == [S.shape[0], S.shape[1]]
    assert p["dtype"] == "complex64"
    assert p["tau"] == TAU
    assert p["backend"] in ("xla", "xla_ref", "pallas")
    assert p["wall_time_s"] > 0
    assert p["spec"]["source"]["shape"] == [S.shape[0], S.shape[1]]


# ------------------------------------------------- artifact methods ----


def test_project_reconstruct_and_errors():
    S = _S(np.complex64)
    basis = build_basis(source=S, strategy="greedy", tau=1e-5)
    c = basis.project(S[:, 0])
    assert c.shape == (basis.k,)
    r = basis.reconstruct(S[:, 0])
    assert float(jnp.linalg.norm(r - S[:, 0])) < 1e-3
    errs = basis.per_column_errors(S)
    assert errs.shape == (S.shape[1],)
    assert float(jnp.max(errs)) < 1e-4


# ------------------------------------------------------- save / load ----


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("strategy", ["greedy", "streamed"])
def test_save_load_round_trip(tmp_path, dtype, strategy):
    """Satellite: bit-identical Q/R/pivots/errs and working eim()/
    roq_weights() after reload, across dtypes and resident/streamed."""
    S = _S(dtype)
    basis = build_basis(source=S, strategy=strategy, tau=TAU, tile_m=40)
    out = tmp_path / f"{strategy}_{np.dtype(dtype).name}"
    basis.save(out)
    loaded = ReducedBasis.load(out)

    assert loaded.k == basis.k
    assert loaded.Q.dtype == basis.Q.dtype
    assert np.array_equal(np.asarray(loaded.Q), np.asarray(basis.Q))
    assert np.array_equal(loaded.pivots, basis.pivots)
    assert np.array_equal(loaded.errs, basis.errs)
    assert np.array_equal(np.asarray(loaded.R), np.asarray(basis.R))
    assert loaded.provenance["strategy"] == strategy
    assert loaded.provenance["dtype"] == np.dtype(dtype).name

    # the reloaded artifact is immediately servable
    ei = loaded.eim()
    assert ei.nodes.shape == (loaded.k,)
    f0 = S[:, int(loaded.pivots[0])]
    interp = ei.B @ f0[ei.nodes]
    assert float(jnp.linalg.norm(interp - f0)) < 1e-2
    w = jnp.ones((S.shape[0],), jnp.float32)
    omega = loaded.roq_weights(S[:, 0], w)
    assert omega.shape == (loaded.k,)
    # ROQ exactness on a basis-span vector: <d, q_0> via full quadrature
    # equals the ROQ sum at the EIM nodes
    q0 = loaded.Q[:, 0]
    full_ip = jnp.sum(w.astype(q0.dtype) * jnp.conj(S[:, 0]) * q0)
    roq_ip = jnp.sum(omega * q0[ei.nodes])
    assert abs(complex(full_ip - roq_ip)) < 5e-3 * max(
        abs(complex(full_ip)), 1.0)


def test_save_load_without_R(tmp_path):
    S = _S()
    basis = build_basis(source=S, strategy="streamed", tau=TAU, tile_m=40,
                        keep_R=False)
    assert basis.R is None
    basis.save(tmp_path)
    loaded = ReducedBasis.load(tmp_path)
    assert loaded.R is None
    assert np.array_equal(np.asarray(loaded.Q), np.asarray(basis.Q))


def test_resave_into_same_directory_loads_newest(tmp_path):
    """save() numbers past existing steps, so a reused directory always
    reloads the artifact written last (no stale-step shadowing)."""
    S = _S()
    build_basis(source=S, strategy="greedy", tau=1e-2).save(tmp_path)
    newer = build_basis(source=S, strategy="greedy", tau=1e-4)
    newer.save(tmp_path)
    loaded = ReducedBasis.load(tmp_path)
    assert loaded.k == newer.k
    assert np.array_equal(np.asarray(loaded.Q), np.asarray(newer.Q))


def test_load_rejects_future_version(tmp_path):
    S = _S()
    build_basis(source=S, strategy="greedy", tau=TAU).save(tmp_path)
    import numpy as _np

    step = tmp_path / "step_00000000"
    arr = _np.load(step / "artifact_version.npy")
    _np.save(step / "artifact_version.npy", arr + 99)
    # keep the manifest CRC consistent with the bumped leaf
    import json
    import zlib

    man = json.loads((step / "manifest.json").read_text())
    new = _np.load(step / "artifact_version.npy")
    for leaf in man["leaves"]:
        if leaf["name"] == "artifact_version":
            leaf["crc32"] = zlib.crc32(new.tobytes())
    (step / "manifest.json").write_text(json.dumps(man))
    with pytest.raises(ValueError, match="artifact version"):
        ReducedBasis.load(tmp_path)


# ------------------------------------------------ deprecation hygiene ----


def test_legacy_mgs_wrapper_warns_and_matches():
    from repro.core import mgs_pivoted_qr

    S = _S(np.complex128)
    with pytest.warns(DeprecationWarning, match="build_basis"):
        legacy = mgs_pivoted_qr(S, tau=TAU)
    front = build_basis(source=S, strategy="mgs", tau=TAU)
    assert front.k == int(legacy.k)
    assert np.array_equal(front.pivots, np.asarray(legacy.pivots))
    assert np.array_equal(np.asarray(front.Q), np.asarray(legacy.Q))


def test_legacy_block_wrapper_warns_and_matches():
    from repro.core.block_greedy import rb_greedy_block

    S = _S(np.complex64)
    with pytest.warns(DeprecationWarning, match="build_basis"):
        legacy = rb_greedy_block(S, tau=TAU, p=2)
    front = build_basis(source=S, strategy="block_greedy", tau=TAU,
                        block_p=2)
    k = int(legacy.k)
    assert front.k == k
    assert np.array_equal(front.pivots, np.asarray(legacy.pivots[:k]))
    assert np.array_equal(np.asarray(front.Q),
                          np.asarray(legacy.Q[:, :k]))


def test_parity_oracle_and_fast_drivers_do_not_warn():
    """rb_greedy_stepwise is the exempt parity oracle; rb_greedy /
    rb_greedy_streamed / pod are strategy engines, not deprecated."""
    from repro.core import rb_greedy_stepwise

    S = _S(np.complex64)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        rb_greedy_stepwise(S, tau=TAU)
        rb_greedy(S, tau=TAU)
        rb_greedy_streamed(S, tau=TAU, tile_m=40)
        pod(S, tau=TAU)
        build_basis(source=S, tau=TAU)


def test_strategies_tuple_is_exhaustive():
    from repro.api.build import _BUILDERS

    # "auto" resolves to a builder; "batched" delegates to
    # build_basis_set (multi-basis artifact) before builder dispatch.
    assert set(STRATEGIES) == set(_BUILDERS) | {"auto", "batched"}
