"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps
+ hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.greedy_update.ops import greedy_update
from repro.kernels.greedy_update.ref import greedy_update_ref
from repro.kernels.imgs_panel.ops import imgs_panel
from repro.kernels.imgs_panel.ref import imgs_panel_ref
from repro.kernels.imgs_project.ops import imgs_project
from repro.kernels.imgs_project.ref import imgs_project_ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _mk(rng, shape, dtype):
    if np.issubdtype(dtype, np.complexfloating):
        return (rng.standard_normal(shape)
                + 1j * rng.standard_normal(shape)).astype(dtype)
    return rng.standard_normal(shape).astype(dtype)


# ------------------------------------------------------------- greedy_update
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("shape", [(64, 96), (300, 700), (1024, 256),
                                   (17, 33)])
def test_greedy_update_sweep(rng, dtype, shape):
    N, M = shape
    S = _mk(rng, (N, M), dtype)
    q = _mk(rng, (N,), dtype)
    q = q / np.linalg.norm(q)
    acc = np.abs(rng.standard_normal(M)).astype(np.float32)
    norms = np.sum(np.abs(S) ** 2, axis=0).astype(np.float32)

    c, a, mx, am = greedy_update(
        jnp.asarray(q), jnp.asarray(S), jnp.asarray(acc), jnp.asarray(norms)
    )
    cr, ar, mxr, amr = greedy_update_ref(
        jnp.asarray(q), jnp.asarray(S), jnp.asarray(acc), jnp.asarray(norms)
    )
    scale = float(jnp.max(jnp.abs(cr))) + 1e-6
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                               rtol=1e-4, atol=1e-4 * scale)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ar),
                               rtol=1e-4, atol=1e-3 * scale ** 2)
    assert float(mx) == pytest.approx(float(mxr), rel=1e-3, abs=1e-2)
    assert int(am) == int(amr)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 9999), n=st.integers(8, 200),
       m=st.integers(8, 300),
       cplx=st.booleans())
def test_greedy_update_property(seed, n, m, cplx):
    rng = np.random.default_rng(seed)
    dtype = np.complex64 if cplx else np.float32
    S = _mk(rng, (n, m), dtype)
    q = _mk(rng, (n,), dtype)
    q /= np.linalg.norm(q)
    acc = np.zeros(m, np.float32)
    norms = np.sum(np.abs(S) ** 2, 0).astype(np.float32)
    c, a, mx, am = greedy_update(jnp.asarray(q), jnp.asarray(S),
                                 jnp.asarray(acc), jnp.asarray(norms))
    cr, ar, mxr, amr = greedy_update_ref(jnp.asarray(q), jnp.asarray(S),
                                         jnp.asarray(acc),
                                         jnp.asarray(norms))
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), rtol=2e-3,
                               atol=2e-3 * (float(np.abs(cr).max()) + 1))
    # residual values agree; index may differ only on numerical ties
    res_k = norms - np.asarray(a)
    res_r = norms - np.asarray(ar)
    assert abs(res_k[int(am)] - res_r[int(amr)]) <= 1e-2 * (
        abs(float(mxr)) + 1.0
    )


# -------------------------------------------------------------- imgs_project
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("shape", [(128, 16), (513, 37), (1000, 100)])
def test_imgs_project_sweep(rng, dtype, shape):
    N, K = shape
    Q = _mk(rng, (N, K), dtype)
    Qo, _ = np.linalg.qr(Q)
    Qo = Qo.astype(dtype)
    v = _mk(rng, (N,), dtype)
    vo, co = imgs_project(jnp.asarray(v), jnp.asarray(Qo))
    vr, cr = imgs_project_ref(jnp.asarray(v), jnp.asarray(Qo))
    np.testing.assert_allclose(np.asarray(vo), np.asarray(vr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(co), np.asarray(cr),
                               rtol=1e-4, atol=1e-4)


def test_imgs_project_orthogonalizes(rng):
    N, K = 256, 32
    Q, _ = np.linalg.qr(rng.standard_normal((N, K)))
    v = rng.standard_normal(N).astype(np.float32)
    vo, _ = imgs_project(jnp.asarray(v), jnp.asarray(Q.astype(np.float32)))
    # after one pass, residual is orthogonal to span(Q) to ~f32 eps
    overlap = np.abs(Q.T @ np.asarray(vo)).max()
    assert overlap < 1e-4 * np.linalg.norm(v)


# ---------------------------------------------------------------- imgs_panel
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("shape", [(128, 16, 4), (513, 37, 5),
                                   (1000, 100, 8), (64, 7, 3)])
def test_imgs_panel_sweep(rng, dtype, shape):
    """The fused panel-projection kernel (interpret mode) matches the
    literal reference on padded and non-sublane-multiple panel widths."""
    N, K, p = shape
    Q = _mk(rng, (N, K), dtype)
    Qo, _ = np.linalg.qr(Q)
    Qo = Qo.astype(dtype)
    V = _mk(rng, (N, p), dtype)
    vo, co = imgs_panel(jnp.asarray(V), jnp.asarray(Qo))
    vr, cr = imgs_panel_ref(jnp.asarray(V), jnp.asarray(Qo))
    assert vo.shape == (N, p) and co.shape == (K, p)
    np.testing.assert_allclose(np.asarray(vo), np.asarray(vr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(co), np.asarray(cr),
                               rtol=1e-4, atol=1e-4)


def test_imgs_panel_matches_columnwise_project(rng):
    """One panel pass == p independent single-vector passes (the BLAS-3
    form changes the execution, not the math)."""
    N, K, p = 256, 32, 6
    Q, _ = np.linalg.qr(rng.standard_normal((N, K)))
    Q = Q.astype(np.float32)
    V = rng.standard_normal((N, p)).astype(np.float32)
    vo, co = imgs_panel(jnp.asarray(V), jnp.asarray(Q))
    for i in range(p):
        vi, ci = imgs_project_ref(jnp.asarray(V[:, i]), jnp.asarray(Q))
        np.testing.assert_allclose(np.asarray(vo[:, i]), np.asarray(vi),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(co[:, i]), np.asarray(ci),
                                   rtol=1e-4, atol=1e-4)
    # and the pass orthogonalizes: residual panel ⟂ span(Q) to ~f32 eps
    overlap = np.abs(Q.T @ np.asarray(vo)).max()
    assert overlap < 1e-4 * float(np.max(np.linalg.norm(V, axis=0)))


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (4, 1)])
def test_flash_attention_sweep(rng, causal, window, hq, hkv):
    B, S, D = 2, 256, 64
    q = (rng.standard_normal((B, hq, S, D)) * 0.3).astype(np.float32)
    k = (rng.standard_normal((B, hkv, S, D)) * 0.3).astype(np.float32)
    v = rng.standard_normal((B, hkv, S, D)).astype(np.float32)
    o = flash_attention_kernel(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), causal=causal, window=window,
                               interpret=True)
    r = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                      causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_ragged_padding(rng):
    """Non-tile-multiple lengths route through padding, still exact."""
    B, H, S, D = 1, 2, 200, 64
    q = (rng.standard_normal((B, H, S, D)) * 0.3).astype(np.float32)
    k = (rng.standard_normal((B, H, S, D)) * 0.3).astype(np.float32)
    v = rng.standard_normal((B, H, S, D)).astype(np.float32)
    o = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=True, use_kernel=True, interpret=True)
    r = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                      causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16(rng):
    B, H, S, D = 1, 2, 128, 128
    q = (rng.standard_normal((B, H, S, D)) * 0.3)
    k = (rng.standard_normal((B, H, S, D)) * 0.3)
    v = rng.standard_normal((B, H, S, D))
    args = [jnp.asarray(x, jnp.bfloat16) for x in (q, k, v)]
    o = flash_attention_kernel(*args, causal=True, interpret=True)
    r = attention_ref(*args, causal=True)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        rtol=5e-2, atol=5e-2,
    )


# ------------------------------------- kernels inside the chunked JAX path
def test_chunked_attention_matches_flash(rng):
    """The pure-JAX online-softmax path (dry-run default) is the same math."""
    from repro.models.attention import _chunked_attn, _einsum_attn

    B, S, H, K, D = 2, 192, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, D)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    for causal, window in [(True, None), (True, 48), (False, None)]:
        a = _chunked_attn(q, k, v, causal, window, chunk=64)
        b = _einsum_attn(q, k, v, causal, window)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# --------------------------------------------- int8 KV quantization property
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 9999), n=st.integers(1, 64),
       hd=st.sampled_from([16, 64, 128]))
def test_kv_quantization_roundtrip(seed, n, hd):
    """|dequant(quant(x)) - x| <= absmax(x)/127 per row (symmetric int8)."""
    from repro.models.attention import dequantize_kv, quantize_kv
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, hd)) * 3.0, jnp.float32)
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    bound = np.asarray(jnp.max(jnp.abs(x), axis=-1, keepdims=True)) / 127.0
    assert np.all(np.abs(np.asarray(back - x)) <= bound + 1e-6)


# -------------------------------------------------- greedy projector property
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 9999))
def test_greedy_projection_idempotent_and_monotone(seed):
    """Q Q^H is a projector; adding bases never increases any column error."""
    from repro.core import rb_greedy
    from repro.core.errors import per_column_errors
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((60, 10)) @ rng.standard_normal((10, 30))
    S = jnp.asarray(A + 1e-6 * rng.standard_normal((60, 30)))
    res = rb_greedy(S, tau=1e-8)
    k = int(res.k)
    Q = res.Q[:, :k]
    P1 = Q @ (Q.conj().T @ S)
    P2 = Q @ (Q.conj().T @ P1)
    np.testing.assert_allclose(np.asarray(P1), np.asarray(P2),
                               rtol=1e-6, atol=1e-9)
    prev = None
    for j in range(1, k + 1):
        errs = np.asarray(per_column_errors(S, res.Q[:, :j]))
        if prev is not None:
            assert np.all(errs <= prev + 1e-8)
        prev = errs
