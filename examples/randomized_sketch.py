"""One-pass randomized sketch of a GW waveform family, then greedy refine.

Greedy streams the snapshot family once per accepted basis vector; the
randomized range-finder (``strategy="randomized"``) streams it ONCE, no
matter the rank: each on-the-fly waveform tile is folded into a small
sketch ``Y = S @ Omega`` whose dense SVD yields the basis and the
spectrum estimates — the only sub-O(k)-pass road to the paper's 0.5 TB
regime.  ``strategy="sketch+greedy"`` then buys back greedy's exact tau
semantics: the sketch basis warm-starts the streamed greedy, which
refines with real pivots only where the sketch fell short.

    python examples/randomized_sketch.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import ReductionSpec, build_basis  # noqa: E402
from repro.gw import chirp_grid, frequency_grid  # noqa: E402


def main():
    f = frequency_grid(20.0, 512.0, 1200)
    m1, m2 = chirp_grid(mc_min=9.0, mc_max=11.0, n_mc=60, n_eta=25)

    # --- one streamed pass: sketch + dense SVD --------------------------
    spec = ReductionSpec.waveform(
        f, m1, m2, dtype=jnp.complex64,
        strategy="randomized", tau=1e-4, max_k=80, tile_m=300,
        sketch_p=10, sketch_power=1,
    )
    N, M = spec.source.shape
    print(f"waveform family: N={N} x M={M} complex64; "
          f"sketch width ell={80 + 10}, passes={1 + 2 * 1}")
    basis = build_basis(spec)
    sk = basis.provenance["sketch"]
    print(f"randomized: rank k={basis.k} from {sk['n_passes']} pass(es) "
          f"over {sk['n_tiles']} tiles in "
          f"{basis.provenance['wall_time_s']:.2f}s")
    est = basis.provenance["sigma_estimates"]
    print(f"  sigma estimates (Ritz): {est[0]:.3e} ... {est[basis.k - 1]:.3e}")

    # --- sketch warm-start + greedy refinement to exact tau -------------
    refined = build_basis(ReductionSpec.waveform(
        f, m1, m2, dtype=jnp.complex64,
        strategy="sketch+greedy", tau=1e-4, max_k=120, tile_m=300,
        sketch_p=10, sketch_power=1, keep_R=False,
    ))
    k0 = refined.provenance["sketch"]["k0"]
    added = int(np.sum(np.asarray(refined.pivots) >= 0))
    print(f"sketch+greedy: sketch seeded k0={k0}, greedy refined with "
          f"{added} pivot(s) to k={refined.k} "
          f"(stop={refined.provenance.get('stop')})")

    # validate both against a resident reconstruction of the family
    S = spec.source.tile(0, M)
    for name, b in (("randomized", basis), ("sketch+greedy", refined)):
        err = float(jnp.max(b.per_column_errors(S)))
        print(f"  {name}: max per-column projection error {err:.3e}")


if __name__ == "__main__":
    main()
