"""Banded reduction: B per-band bases in ONE fused pass, then served.

The pyNekTools-style banded workload: FFT the sample axis of a chirp
family, slice the spectrum into B contiguous bands, and reduce each band
with its own basis.  A narrow band's family is far smoother than the
broadband signal, so per-band bases are tiny at equal tau — and the B
band matrices share one (N_b, M) shape, which is exactly the stacked
workload ``strategy="batched"`` builds in one lockstep sweep instead of
B sequential greedy runs.  The resulting ``ReducedBasisSet`` registers
its children with the serving ``BasisRouter`` (one route per band), and
the ``ROQEngine`` interpolates held-out signals band by band.

    python examples/banded_bases.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.api import ReducedBasisSet, build_basis  # noqa: E402
from repro.data import band_split  # noqa: E402
from repro.serving import BasisRouter, ROQEngine  # noqa: E402


def chirp_family(n=1024, m=160, seed=0):
    """Real time-domain chirps h(t) = sin(2*pi*(f0*t + c*t^2/2)) over a
    (f0, c) grid — a stand-in for a time-domain detector-frame family."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0.0, 1.0, n, endpoint=False)
    f0 = rng.uniform(12.0, 48.0, size=m)
    c = rng.uniform(30.0, 120.0, size=m)
    S = np.sin(2 * np.pi * (f0[None, :] * t[:, None]
                            + 0.5 * c[None, :] * t[:, None] ** 2))
    return np.asarray(S, dtype=np.float32)


def main():
    S = chirp_family()
    split = band_split(S, bands=8)          # rFFT -> (8, N_b, M) complex
    B, Nb, M = split.stack.shape
    print(f"chirp family {S.shape} -> {B} bands x ({Nb} bins, {M} cols); "
          f"rFFT bins {split.n_freq}, edges {split.edges[0]}.."
          f"{split.edges[-1]}")

    with tempfile.TemporaryDirectory() as tmp:
        workdir = os.path.join(tmp, "bands")
        bset = build_basis(source=split, strategy="batched", tau=1e-5,
                           max_k=64, workdir=workdir)
        ks = [b.k for b in bset]
        print(f"batched build: {B} bases in one fused pass, "
              f"k per band = {ks} "
              f"({bset.provenance['wall_time_s']:.2f}s)")

        # the set is one atomic artifact directory: B children + set.json
        bset = ReducedBasisSet.load(workdir)

        # one serving route per band (directory-backed => evictable)
        router = BasisRouter()
        ids = bset.register(router, prefix="band")
        engine = ROQEngine(router, max_batch=16, max_wait_ms=1.0)
        try:
            held_out = np.fft.rfft(chirp_family(m=3, seed=7), axis=0)
            worst = 0.0
            for b, bid in enumerate(ids):
                lo, hi = split.edges[b]
                col = held_out[lo:hi, 0]
                basis, eim = engine.router.get(bid)
                fut = engine.submit(bid, col[np.asarray(eim.nodes)])
                rec = fut.result(timeout=30)
                err = float(np.max(np.abs(rec - col)))
                worst = max(worst, err / (np.max(np.abs(col)) + 1e-30))
            print(f"served {B} per-band interpolations; worst relative "
                  f"EIM error {worst:.3e}")
            print(f"engine metrics: {engine.metrics.snapshot()}")
        finally:
            engine.close()


if __name__ == "__main__":
    main()
