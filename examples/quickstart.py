"""Quickstart: build a reduced basis for gravitational waveforms.

The 60-second tour of the paper's pipeline, through the one front door
(:mod:`repro.api`):
  1. generate a snapshot matrix from the TaylorF2 waveform family,
  2. ``build_basis`` it to a target tolerance (RB-greedy under the hood),
  3. compare against POD (Algorithm 1) and the reconstruction (Algorithm 4),
  4. build an empirical interpolant (EIM) and validate out-of-sample,
  5. save the artifact and reload it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.api import ReducedBasis, build_basis
from repro.core import empirical_interpolant, reconstruction
from repro.core.errors import orthogonality_defect, proj_error_max
from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid
from repro.gw.grids import random_mass_samples


def main():
    # 1. snapshots: h(f; m1, m2) on a 60x15 chirp-mass grid
    f = frequency_grid(20.0, 512.0, 1500)
    m1, m2 = chirp_grid(n_mc=60, n_eta=15)
    S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128)
    print(f"snapshot matrix S: {S.shape} {S.dtype} "
          f"({S.size * 16 / 1e6:.1f} MB)")

    # 2. one front door: strategy="auto" resolves to the resident chunked
    #    greedy driver at this shape (see the repro.api log line)
    tau = 1e-6
    basis = build_basis(source=S, tau=tau)
    k = basis.k
    print(f"greedy basis: k = {k} of {S.shape[1]} columns "
          f"(compression {S.shape[1] / k:.1f}x)")
    print(f"  max projection error: "
          f"{float(jnp.max(basis.per_column_errors(S))):.2e}"
          f" (tau = {tau:.0e})")
    print(f"  orthogonality defect: "
          f"{float(orthogonality_defect(basis.Q)):.2e}")
    print(f"  error decay: "
          f"{[f'{float(e):.1e}' for e in basis.errs[::max(1, k // 8)]]}")

    # 3. POD comparison (Theorem 3.2 / Remark 4.2) — same front door,
    #    different strategy
    p = build_basis(source=S, strategy="pod", tau=tau)
    print(f"POD rank at same tau (2-norm): k = {p.k} "
          f"(greedy uses max-norm; Cor. 4.4 orders the criteria)")
    rec = reconstruction(S, tau1=tau * 1e-2, tau2=tau)
    print(f"reconstruction (Alg. 4): j = {rec.j} QR terms -> "
          f"k = {int(rec.k)} SVD-rotated bases")

    # 4. EIM + out-of-sample validation (greedycpp's validation step)
    ei = basis.eim()
    mv1, mv2 = random_mass_samples(200, 7.0, 25.0, seed=7)
    V = build_snapshot_matrix(f, mv1, mv2, dtype=jnp.complex128)
    errs = [
        float(jnp.linalg.norm(
            empirical_interpolant(ei.B, ei.nodes, V[:, i]) - V[:, i]))
        for i in range(V.shape[1])
    ]
    print(f"EIM: {k} nodes; out-of-sample interpolation error "
          f"median {np.median(errs):.2e} / max {np.max(errs):.2e}")

    # 5. the basis is a durable artifact: save, reload, reuse
    with tempfile.TemporaryDirectory() as td:
        basis.save(td)
        again = ReducedBasis.load(td)
        same = bool(jnp.all(again.Q == basis.Q))
        print(f"save/load round trip: bit-identical Q = {same}, "
              f"provenance strategy = {again.provenance['strategy']!r}")


if __name__ == "__main__":
    main()
