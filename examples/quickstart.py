"""Quickstart: build a reduced basis for gravitational waveforms.

The 60-second tour of the paper's pipeline:
  1. generate a snapshot matrix from the TaylorF2 waveform family,
  2. run RB-greedy (Algorithm 3) to a target tolerance,
  3. compare against POD (Algorithm 1) and the reconstruction (Algorithm 4),
  4. build an empirical interpolant (EIM) and validate out-of-sample.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (
    eim_nodes, empirical_interpolant, pod, rb_greedy, reconstruction,
)
from repro.core.errors import proj_error_max, orthogonality_defect
from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid
from repro.gw.grids import random_mass_samples


def main():
    # 1. snapshots: h(f; m1, m2) on a 60x15 chirp-mass grid
    f = frequency_grid(20.0, 512.0, 1500)
    m1, m2 = chirp_grid(n_mc=60, n_eta=15)
    S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128)
    print(f"snapshot matrix S: {S.shape} {S.dtype} "
          f"({S.size * 16 / 1e6:.1f} MB)")

    # 2. RB-greedy to tau = 1e-6
    tau = 1e-6
    res = rb_greedy(S, tau=tau)
    k = int(res.k)
    print(f"greedy basis: k = {k} of {S.shape[1]} columns "
          f"(compression {S.shape[1] / k:.1f}x)")
    print(f"  max projection error: {float(proj_error_max(S, res.Q[:, :k])):.2e}"
          f" (tau = {tau:.0e})")
    print(f"  orthogonality defect: "
          f"{float(orthogonality_defect(res.Q[:, :k])):.2e}")
    print(f"  error decay: {[f'{float(e):.1e}' for e in res.errs[:k:k//8]]}")

    # 3. POD comparison (Theorem 3.2 / Remark 4.2)
    p = pod(S, tau=tau)
    print(f"POD rank at same tau (2-norm): k = {int(p.k)} "
          f"(greedy uses max-norm; Cor. 4.4 orders the criteria)")
    rec = reconstruction(S, tau1=tau * 1e-2, tau2=tau)
    print(f"reconstruction (Alg. 4): j = {rec.j} QR terms -> "
          f"k = {int(rec.k)} SVD-rotated bases")

    # 4. EIM + out-of-sample validation (greedycpp's validation step)
    ei = eim_nodes(res.Q[:, :k])
    mv1, mv2 = random_mass_samples(200, 7.0, 25.0, seed=7)
    V = build_snapshot_matrix(f, mv1, mv2, dtype=jnp.complex128)
    errs = [
        float(jnp.linalg.norm(
            empirical_interpolant(ei.B, ei.nodes, V[:, i]) - V[:, i]))
        for i in range(V.shape[1])
    ]
    print(f"EIM: {k} nodes; out-of-sample interpolation error "
          f"median {np.median(errs):.2e} / max {np.max(errs):.2e}")


if __name__ == "__main__":
    main()
