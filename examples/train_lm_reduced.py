"""End-to-end driver: train a ~100M-param LM for a few hundred steps, then
apply the paper's technique to its hidden states.

Uses the production trainer (microbatching, AdamW, checkpointing, step-keyed
data) on a scaled-down stablelm-family config sized to ~100M params, then
demonstrates the framework integration: snapshot the final hidden states
over a parameter sweep (prompts) and build a greedy reduced basis of the
activation subspace — the LM as the snapshot generator `nu -> M(x; nu)`.

Run:  PYTHONPATH=src python examples/train_lm_reduced.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import build_basis
from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.models import api
from repro.training import make_train_step, train_state_init


def hundred_m_config():
    """~100M-parameter member of the stablelm family."""
    return get_config("stablelm-3b").replace(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, d_ff=1408,
        vocab_size=32768, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = hundred_m_config()
    print(f"config: {cfg.n_layers}L d{cfg.d_model} "
          f"~{cfg.param_count()/1e6:.0f}M params")

    state = train_state_init(cfg, jax.random.key(0))
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch)
    step = make_train_step(cfg, n_microbatches=2, base_lr=3e-4,
                           warmup=args.steps // 10, total_steps=args.steps)

    t0 = time.time()
    first = None
    for i in range(args.steps):
        state, m = step(state, data.batch(i))
        if i == 0:
            first = float(m["loss"])
        if (i + 1) % 25 == 0:
            print(f"step {i+1:4d}  loss {float(m['loss']):.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print(f"loss: {first:.3f} -> {float(m['loss']):.3f} "
          f"in {args.steps} steps / {time.time()-t0:.0f}s")

    # ---- the paper's technique on the trained model ----
    # The paper's premise (Sec. 1): reduction pays off when the snapshots
    # vary SMOOTHLY with a parameter.  Token IDs are categorical, so a
    # prompt sweep is NOT smooth — contrast three sweeps of the model's
    # output distribution p(nu) to see where the premise bites:
    #   (a) independent random prompts          -> near full rank,
    #   (b) temperature sweep of one prompt:
    #       M(x; nu) = softmax(logits / nu)     -> smooth in nu, low rank,
    #   (c) consecutive positions of one long sequence (feature-cache
    #       correlation along time)             -> partially compressible.
    n_snap = 160

    def last_logits(toks):
        out = api.forward_logits(cfg, state.params, {"tokens": toks})
        return out[0, -1, :].astype(jnp.float32)

    cols_rand = []
    for s in range(n_snap):
        toks = jax.random.randint(jax.random.key(s), (1, args.seq), 0,
                                  cfg.vocab_size)
        cols_rand.append(np.asarray(jax.nn.softmax(last_logits(toks)),
                                    np.float64))

    base_toks = data.batch(0)["tokens"][:1]
    z = last_logits(base_toks)
    cols_temp = [
        np.asarray(jax.nn.softmax(z / t), np.float64)
        for t in np.linspace(0.5, 2.0, n_snap)
    ]

    long_logits = api.forward_logits(
        cfg, state.params, {"tokens": data.batch(1)["tokens"][:1]}
    )[0].astype(jnp.float32)
    pos = np.linspace(args.seq // 4, args.seq - 1, n_snap).astype(int)
    cols_pos = [np.asarray(jax.nn.softmax(long_logits[i]), np.float64)
                for i in pos]

    for name, cols in (("(a) random prompts", cols_rand),
                       ("(b) temperature sweep", cols_temp),
                       ("(c) position sweep", cols_pos)):
        S = jnp.asarray(np.stack(cols, axis=1))
        S = S / jnp.linalg.norm(S, axis=0, keepdims=True)
        k = build_basis(source=S, strategy="greedy", tau=1e-3).k
        print(f"{name}: greedy basis k = {k}/{S.shape[1]} "
              f"({S.shape[1]/max(k,1):.1f}x compression at tau=1e-3)")
    print("=> unstructured sweeps are near full rank; smooth parametric "
          "families compress — exactly the paper's n-width premise.")


if __name__ == "__main__":
    main()
