"""Distributed greedy reduction on an 8-device host mesh.

Demonstrates the paper's Sec. 6 system end-to-end on forced host devices:
column-sharded snapshot matrix, SPMD pivot search + psum column broadcast,
checkpoint + elastic restart on a different device count.

Run:  PYTHONPATH=src python examples/distributed_greedy_demo.py
(re-executes itself with XLA_FLAGS for 8 host devices)
"""

import os
import subprocess
import sys

BODY = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
import tempfile, time
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import rb_greedy
from repro.core.distributed import distributed_greedy
from repro.compat import make_auto_mesh
from repro.core.errors import proj_error_max
from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid

print(f"devices: {len(jax.devices())}")
f = frequency_grid(20.0, 512.0, 1000)
m1, m2 = chirp_grid(n_mc=64, n_eta=8)
mesh = make_auto_mesh((8,), ("cols",))
sharding = NamedSharding(mesh, P(None, ("cols",)))
S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128,
                          sharding=sharding)
print(f"S: {S.shape} sharded over {mesh.shape} "
      f"({S.size*16/1e6:.0f} MB, {S.size*16/8e6:.0f} MB/device)")

t0 = time.time()
res = distributed_greedy(S, tau=1e-6, max_k=min(*S.shape), mesh=mesh)
k = int(res.k)
print(f"distributed greedy: k={k} in {time.time()-t0:.1f}s, "
      f"max err {float(proj_error_max(S, jnp.asarray(np.array(res.Q[:, :k])))):.2e}")

ser = rb_greedy(jax.device_get(S), tau=1e-6)
print(f"matches serial: k {int(ser.k)}=={k}, pivots equal: "
      f"{bool(np.array_equal(np.array(ser.pivots[:k]), np.array(res.pivots[:k])))}")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    raise SystemExit(subprocess.run([sys.executable, "-c", BODY],
                                    env=env).returncode)


if __name__ == "__main__":
    main()
