"""Distributed greedy reduction on an 8-device host mesh.

Demonstrates the paper's Sec. 6 system end-to-end on forced host devices:
column-sharded snapshot matrix, SPMD pivot search + psum column broadcast,
checkpoint + elastic restart on a different device count.

Run:  PYTHONPATH=src python examples/distributed_greedy_demo.py
(re-executes itself with XLA_FLAGS for 8 host devices)
"""

import os
import subprocess
import sys

BODY = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
import tempfile, time
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api import build_basis
from repro.compat import make_auto_mesh
from repro.core.errors import proj_error_max
from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid

print(f"devices: {len(jax.devices())}")
f = frequency_grid(20.0, 512.0, 1000)
m1, m2 = chirp_grid(n_mc=64, n_eta=8)
mesh = make_auto_mesh((8,), ("cols",))
sharding = NamedSharding(mesh, P(None, ("cols",)))
S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128,
                          sharding=sharding)
print(f"S: {S.shape} sharded over {mesh.shape} "
      f"({S.size*16/1e6:.0f} MB, {S.size*16/8e6:.0f} MB/device)")

# one front door: passing a mesh flips strategy="auto" to "distributed"
t0 = time.time()
basis = build_basis(source=S, tau=1e-6, mesh=mesh)
k = basis.k
print(f"distributed greedy: k={k} in {time.time()-t0:.1f}s, "
      f"max err {float(proj_error_max(S, jnp.asarray(np.array(basis.Q)))):.2e}")

ser = build_basis(source=jax.device_get(S), strategy="greedy", tau=1e-6)
kk = min(ser.k, k)  # compare the shared prefix if ranks differ at tau
print(f"matches serial: k {ser.k}=={k}, pivots equal: "
      f"{bool(np.array_equal(ser.pivots[:kk], basis.pivots[:kk]))}")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    raise SystemExit(subprocess.run([sys.executable, "-c", BODY],
                                    env=env).returncode)


if __name__ == "__main__":
    main()
