"""Out-of-core GW basis build: snapshots generated on the fly, never stored.

The paper's headline run reduces a ~0.5 TB snapshot matrix that no single
worker can hold.  This example reproduces that regime's *structure* at demo
scale: a :class:`repro.data.WaveformProvider` generates TaylorF2 snapshot
tiles on demand from a (chirp mass, eta) grid — the full matrix never
exists — and :func:`repro.core.rb_greedy_streamed` sweeps the tiles with
peak device memory O(N * (max_k + tile_m)), checkpointing mid-build so a
killed job resumes from the last completed tile:

    python examples/streaming_gw.py            # build (interrupt freely)
    python examples/streaming_gw.py            # re-run: resumes, no rework
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.core import rb_greedy_streamed  # noqa: E402
from repro.data import WaveformProvider  # noqa: E402
from repro.gw import chirp_grid, frequency_grid  # noqa: E402


def main():
    f = frequency_grid(20.0, 512.0, 2000)
    # narrow chirp-mass band: the family's n-width decays within ~60 bases
    m1, m2 = chirp_grid(mc_min=9.0, mc_max=11.0, n_mc=120, n_eta=40)
    prov = WaveformProvider(f, m1, m2, dtype=jnp.complex64)
    N, M = prov.shape
    tile_m = 600
    print(f"provider: N={N} x M={M} complex64 "
          f"(~{N * M * 8 / 1e6:.0f} MB if materialized), tile_m={tile_m} "
          f"-> device peak ~{N * (96 + tile_m) * 8 / 1e6:.1f} MB")

    ckpt = os.path.join(os.path.dirname(__file__), "_streaming_ckpt")
    res = rb_greedy_streamed(
        prov, tau=1e-4, max_k=96, tile_m=tile_m, keep_R=False,
        checkpoint_dir=ckpt, checkpoint_every_tiles=2, resume=True,
        callback=lambda i: print(
            f"  basis {i['k']:3d}  pivot {i['pivot']:5d}  "
            f"err {i['err']:.3e}"),
    )
    print(f"built k={res.k} bases over {res.n_tiles} tiles/sweep")

    # out-of-sample validation against freshly generated waveforms
    rng = np.random.default_rng(7)
    Q = res.Q[:, :res.k]
    worst = 0.0
    for _ in range(50):
        j = int(rng.integers(0, M))
        h = prov.column(j)
        r = h - Q @ (Q.conj().T @ h)
        worst = max(worst, float(jnp.linalg.norm(r)))
    print(f"max in-grid residual over 50 spot checks: {worst:.3e}")


if __name__ == "__main__":
    main()
