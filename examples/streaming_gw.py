"""Out-of-core GW basis build: snapshots generated on the fly, never stored.

The paper's headline run reduces a ~0.5 TB snapshot matrix that no single
worker can hold.  This example reproduces that regime's *structure* at demo
scale through the front door: ``ReductionSpec.waveform`` wraps a (chirp
mass, eta) grid in a :class:`repro.data.WaveformProvider` that generates
TaylorF2 snapshot tiles on demand — the full matrix never exists — and
``build_basis(strategy="streamed")`` sweeps the tiles with peak device
memory O(N * (max_k + 2*tile_m)), checkpointing mid-build so a killed job
resumes from the last completed tile:

    python examples/streaming_gw.py            # build (interrupt freely)
    python examples/streaming_gw.py            # re-run: resumes, no rework
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.api import ReductionSpec, build_basis  # noqa: E402
from repro.gw import chirp_grid, frequency_grid  # noqa: E402


def main():
    f = frequency_grid(20.0, 512.0, 2000)
    # narrow chirp-mass band: the family's n-width decays within ~60 bases
    m1, m2 = chirp_grid(mc_min=9.0, mc_max=11.0, n_mc=120, n_eta=40)
    tile_m = 600
    ckpt = os.path.join(os.path.dirname(__file__), "_streaming_ckpt")
    # a waveform-grid spec: snapshot columns generated on the fly, the
    # matrix never materialized (the paper's out-of-core regime)
    spec = ReductionSpec.waveform(
        f, m1, m2, dtype=jnp.complex64,
        strategy="streamed", tau=1e-4, max_k=96, tile_m=tile_m,
        keep_R=False, checkpoint_dir=ckpt, checkpoint_every_tiles=2,
        resume=True,
        callback=lambda i: print(
            f"  basis {i['k']:3d}  pivot {i['pivot']:5d}  "
            f"err {i['err']:.3e}"),
    )
    prov = spec.source
    N, M = prov.shape
    print(f"provider: N={N} x M={M} complex64 "
          f"(~{N * M * 8 / 1e6:.0f} MB if materialized), tile_m={tile_m} "
          f"-> device peak ~{N * (96 + 2 * tile_m) * 8 / 1e6:.1f} MB "
          f"(current + prefetched tile)")

    basis = build_basis(spec)
    print(f"built k={basis.k} bases over {-(-M // tile_m)} tiles/sweep")

    # out-of-sample validation against freshly generated waveforms
    rng = np.random.default_rng(7)
    worst = 0.0
    for _ in range(50):
        j = int(rng.integers(0, M))
        h = prov.column(j)
        r = h - basis.reconstruct(h)
        worst = max(worst, float(jnp.linalg.norm(r)))
    print(f"max in-grid residual over 50 spot checks: {worst:.3e}")


if __name__ == "__main__":
    main()
