"""Reduced-order quadrature for GW likelihoods (the paper's application).

Builds the full ROQ pipeline the greedycpp code serves in LIGO inference
(Refs. [6, 12, 37] of the paper): greedy basis -> EIM nodes -> ROQ weights,
then evaluates the inner products <d, h(nu)> two ways — full quadrature vs
ROQ — over a batch of "requests" (parameter draws), reporting accuracy and
the operation-count reduction.

Run:  PYTHONPATH=src python examples/gw_roq.py
"""

import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.api import build_basis
from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid
from repro.gw.grids import random_mass_samples
from repro.gw.waveform import taylorf2


def main():
    # ---- offline stage (what greedycpp runs on the cluster) ----
    N = 2000
    f = frequency_grid(20.0, 512.0, N)
    m1, m2 = chirp_grid(n_mc=50, n_eta=12)
    S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128)
    basis = build_basis(source=S, tau=1e-6)   # one front door (repro.api)
    k = basis.k
    ei = basis.eim()
    print(f"offline: basis k = {k}, EIM nodes selected from N = {N} bins")

    # synthetic "data" = signal + noise, quadrature = uniform df
    rng = np.random.default_rng(0)
    fj = jnp.asarray(f)
    data = taylorf2(fj, 12.0, 9.0, dtype=jnp.complex128) + 0.05 * (
        jnp.asarray(rng.standard_normal(N))
        + 1j * jnp.asarray(rng.standard_normal(N))
    )
    w = jnp.full((N,), float(f[1] - f[0]))
    omega = basis.roq_weights(data, w)  # (k,) precomputed ROQ weights

    # ---- online stage: batched likelihood-style inner products ----
    n_req = 256
    q1, q2 = random_mass_samples(n_req, 7.0, 25.0, seed=3)

    def full_ip(a, b):
        h = taylorf2(fj, a, b, dtype=jnp.complex128)
        return jnp.sum(w * jnp.conj(data) * h)

    def roq_ip(a, b):
        # note: evaluating on the full grid here only to apply the training
        # normalization convention; a production ROQ normalizes via a
        # separate quadratic-term basis for <h, h> (out of scope here) and
        # evaluates the model at the k EIM nodes only.
        h = taylorf2(fj, a, b, dtype=jnp.complex128)
        return jnp.sum(omega * h[ei.nodes])

    full_v = jax.jit(jax.vmap(full_ip))(jnp.asarray(q1), jnp.asarray(q2))
    roq_v = jax.jit(jax.vmap(roq_ip))(jnp.asarray(q1), jnp.asarray(q2))
    rel = np.abs(np.asarray(full_v - roq_v)) / np.abs(np.asarray(full_v))
    print(f"online: {n_req} requests; ROQ inner-product relative error "
          f"median {np.median(rel):.2e} / max {np.max(rel):.2e}")
    print(f"operation count per request: full = O({2 * N}) mul-adds, "
          f"ROQ = O({2 * k}) -> {N / k:.0f}x reduction")

    # wall-time comparison of the summation stage alone
    hs = jax.vmap(lambda a, b: taylorf2(fj, a, b, dtype=jnp.complex128))(
        jnp.asarray(q1), jnp.asarray(q2))
    sum_full = jax.jit(lambda H: jnp.sum(w * jnp.conj(data) * H, axis=-1))
    sum_roq = jax.jit(lambda H: jnp.sum(omega * H[:, ei.nodes], axis=-1))
    jax.block_until_ready(sum_full(hs)); jax.block_until_ready(sum_roq(hs))
    t0 = time.perf_counter(); jax.block_until_ready(sum_full(hs))
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter(); jax.block_until_ready(sum_roq(hs))
    t_roq = time.perf_counter() - t0
    print(f"summation wall-time: full {t_full*1e3:.2f} ms vs "
          f"ROQ {t_roq*1e3:.2f} ms ({t_full/max(t_roq,1e-9):.1f}x)")


if __name__ == "__main__":
    main()
